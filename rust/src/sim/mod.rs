//! Cycle-accurate simulator of the FLICKER accelerator (paper Sec. IV) and
//! its baselines.
//!
//! The simulated machine follows Fig. 5: per tile, four *sub-tile complexes*
//! each consisting of a CTU (two PRTUs + MMU, fully pipelined, with a small
//! built-in FIFO for stall resilience) feeding four feature FIFOs; each FIFO
//! drives a channel of two VRUs rendering one 4×4 mini-tile. Preprocessing
//! cores and sorting units run a tile ahead (double-buffered), so the frame
//! bottleneck is max(rendering pipeline, preprocessing compute, DRAM).
//!
//! Baselines share the same template:
//! * **GSCore** [7] — OBB sub-tile test in preprocessing, no CTU, 64 VRUs.
//! * **FLICKER-simplified** — sub-tile AABB only, no CTU (the ablation of
//!   Fig. 8), in 32- and 64-VRU flavours (Table II(b)).
//! * **Edge/desktop GPU** — analytic SM model with warp-divergence
//!   accounting (`gpu`), for Fig. 1 and the Fig. 10 normalization.

pub mod area;
pub mod dram;
pub mod energy;
pub mod gpu;
pub mod pipe;
pub mod top;
pub mod workload;

use crate::cat::{LeaderMode, Precision};

/// Sub-tile pre-filter performed by the preprocessing core (Stage 1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SubtileTest {
    /// Tile-level AABB only: every sub-tile of an intersected tile is fed.
    None,
    /// Sub-tile AABB (FLICKER Stage 1).
    Aabb,
    /// Sub-tile OBB (GSCore).
    Obb,
}

/// Hardware configuration (paper Table II(a) plus ablation knobs).
#[derive(Clone, Debug)]
pub struct HwConfig {
    /// Preset name ("flicker32", "gscore64", …).
    pub name: String,
    /// Core clock (paper-class edge accelerator: 1 GHz at 28 nm).
    pub freq_ghz: f64,
    /// Rendering cores; each covers one 8×8 sub-tile.
    pub rendering_cores: usize,
    /// Channels per rendering core; each renders one 4×4 mini-tile.
    pub channels_per_core: usize,
    /// VRUs per channel (pixels blended per cycle per channel ×8).
    pub vrus_per_channel: usize,
    /// Contribution-aware test unit present?
    pub ctu: bool,
    /// Leader-pixel mode the CTU runs (ignored without CTU).
    pub cat_mode: LeaderMode,
    /// CTU datapath precision.
    pub cat_precision: Precision,
    /// Stage-1 sub-tile test.
    pub subtile_test: SubtileTest,
    /// Feature-FIFO depth per channel (Fig. 9 sweep knob).
    pub fifo_depth: usize,
    /// Depth of the CTU's built-in stall-resilience FIFO.
    pub ctu_fifo_depth: usize,
    /// DRAM bandwidth (LPDDR4: 51.2 GB/s).
    pub dram_gbps: f64,
    /// Use clustering ("big Gaussians") for frustum-culling traffic.
    pub clustering: bool,
}

impl HwConfig {
    /// Total VRU count across all rendering cores.
    pub fn total_vrus(&self) -> usize {
        self.rendering_cores * self.channels_per_core * self.vrus_per_channel
    }

    /// Cycles one channel needs to blend one Gaussian over its mini-tile
    /// (16 pixels / VRUs-per-channel).
    pub fn blend_cycles(&self) -> u32 {
        16u32.div_ceil(self.vrus_per_channel as u32)
    }

    /// FLICKER as evaluated: 4 cores × 4 ch × 2 VRUs = 32 VRUs, CTU with
    /// adaptive leaders at mixed precision, sub-tile AABB Stage 1, FIFO 16.
    pub fn flicker32() -> HwConfig {
        HwConfig {
            name: "flicker32".into(),
            freq_ghz: 1.0,
            rendering_cores: 4,
            channels_per_core: 4,
            vrus_per_channel: 2,
            ctu: true,
            cat_mode: LeaderMode::SmoothFocused,
            cat_precision: Precision::Mixed,
            subtile_test: SubtileTest::Aabb,
            fifo_depth: 16,
            ctu_fifo_depth: 4,
            dram_gbps: 51.2,
            clustering: true,
        }
    }

    /// FLICKER forced to Uniform-Sparse (the +1.1× mode of Fig. 8).
    pub fn flicker32_sparse() -> HwConfig {
        HwConfig {
            name: "flicker32-sparse".into(),
            cat_mode: LeaderMode::UniformSparse,
            ..Self::flicker32()
        }
    }

    /// Ablation: FLICKER without the CTU (basic sub-tile AABB only).
    pub fn simplified32() -> HwConfig {
        HwConfig {
            name: "flicker-simplified32".into(),
            ctu: false,
            ..Self::flicker32()
        }
    }

    /// Simplified version scaled to 64 VRUs (Table II(b) baseline).
    pub fn simplified64() -> HwConfig {
        HwConfig {
            name: "flicker-simplified64".into(),
            ctu: false,
            vrus_per_channel: 4,
            ..Self::flicker32()
        }
    }

    /// GSCore-like baseline: OBB sub-tile test, 64 VRUs, no CTU.
    pub fn gscore64() -> HwConfig {
        HwConfig {
            name: "gscore64".into(),
            ctu: false,
            vrus_per_channel: 4,
            subtile_test: SubtileTest::Obb,
            clustering: false,
            ..Self::flicker32()
        }
    }

    /// Resolve a hardware preset by CLI/config name.
    pub fn by_name(name: &str) -> Option<HwConfig> {
        Some(match name {
            "flicker32" | "flicker" => Self::flicker32(),
            "flicker32-sparse" | "sparse" => Self::flicker32_sparse(),
            "flicker-simplified32" | "simplified32" => Self::simplified32(),
            "flicker-simplified64" | "simplified64" => Self::simplified64(),
            "gscore64" | "gscore" => Self::gscore64(),
            _ => return None,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vru_counts_match_paper() {
        assert_eq!(HwConfig::flicker32().total_vrus(), 32);
        assert_eq!(HwConfig::gscore64().total_vrus(), 64);
        assert_eq!(HwConfig::simplified64().total_vrus(), 64);
    }

    #[test]
    fn blend_cycles() {
        assert_eq!(HwConfig::flicker32().blend_cycles(), 8);
        assert_eq!(HwConfig::gscore64().blend_cycles(), 4);
    }

    #[test]
    fn presets_resolvable_by_name() {
        for n in [
            "flicker32",
            "gscore64",
            "simplified32",
            "simplified64",
            "flicker32-sparse",
        ] {
            assert!(HwConfig::by_name(n).is_some(), "{n}");
        }
        assert!(HwConfig::by_name("nope").is_none());
    }

    #[test]
    fn gscore_has_obb_no_ctu() {
        let g = HwConfig::gscore64();
        assert!(!g.ctu);
        assert_eq!(g.subtile_test, SubtileTest::Obb);
    }
}
