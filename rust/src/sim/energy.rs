//! Energy model: 28 nm per-operation energies + DRAM access energy.
//!
//! Per-op numbers follow the widely used 45 nm estimates (Horowitz,
//! ISSCC'14) scaled to 28 nm (~0.6×), consistent with the accelerator
//! literature the paper cites ([22][24]-class designs). Absolute joules are
//! *not* the claim — the comparisons in Figs. 8/10 are ratios on the same
//! model, which is exactly how the paper's own simulator-based energy
//! numbers work.

use super::workload::FrameWorkload;
use super::HwConfig;
use crate::cat::Precision;
use crate::render::precision::{class_index, CLASSES};

/// Per-op energies in picojoules (28 nm).
#[derive(Clone, Copy, Debug)]
pub struct EnergyParams {
    /// FP32 multiply.
    pub fp32_mul_pj: f64,
    /// FP32 add.
    pub fp32_add_pj: f64,
    /// FP16 multiply.
    pub fp16_mul_pj: f64,
    /// FP16 add.
    pub fp16_add_pj: f64,
    /// FP8 multiply.
    pub fp8_mul_pj: f64,
    /// FP8 add.
    pub fp8_add_pj: f64,
    /// On-chip SRAM access per 32-bit word.
    pub sram_word_pj: f64,
    /// DRAM energy per byte (LPDDR4-class).
    pub dram_byte_pj: f64,
    /// Static/clock power per unit-cycle (VRU-equivalent), pJ.
    pub static_unit_cycle_pj: f64,
    /// Board/system power floor (W): DRAM refresh, IO, PLLs, regulators —
    /// what a deployed edge module burns beyond the datapath. Keeps the
    /// accelerator-vs-GPU energy ratios at the paper's scale (the XNX
    /// baseline is measured at board power).
    pub system_w: f64,
}

impl Default for EnergyParams {
    fn default() -> Self {
        EnergyParams {
            fp32_mul_pj: 2.3,
            fp32_add_pj: 0.55,
            fp16_mul_pj: 0.70,
            fp16_add_pj: 0.25,
            fp8_mul_pj: 0.20,
            fp8_add_pj: 0.10,
            sram_word_pj: 3.0,
            dram_byte_pj: 21.0,
            static_unit_cycle_pj: 0.15,
            system_w: 0.8,
        }
    }
}

/// Energy breakdown for one frame, in microjoules.
#[derive(Clone, Copy, Debug, Default)]
pub struct EnergyReport {
    /// Blending (VRU) energy.
    pub vru_uj: f64,
    /// Contribution-test (CTU) energy.
    pub ctu_uj: f64,
    /// Feature-FIFO energy.
    pub fifo_uj: f64,
    /// Preprocessing-core energy.
    pub preprocess_uj: f64,
    /// DRAM traffic energy.
    pub dram_uj: f64,
    /// Static/system-floor energy.
    pub static_uj: f64,
}

impl EnergyReport {
    /// Total frame energy.
    pub fn total_uj(&self) -> f64 {
        self.vru_uj + self.ctu_uj + self.fifo_uj + self.preprocess_uj + self.dram_uj
            + self.static_uj
    }
}

/// Blend cost per (pixel, Gaussian): Eq. 1 evaluation + color accumulation
/// ≈ 9 FP16 muls + 6 FP16 adds + exp (≈ 4 mul-equivalents) on the VRU's
/// full-FP16 rendering datapath.
fn blend_pair_pj(p: &EnergyParams) -> f64 {
    13.0 * p.fp16_mul_pj + 6.0 * p.fp16_add_pj
}

/// CTU energy per PR at the given precision (Alg. 1: 20 mul + 8 add
/// + 4 cmp on the quantized path, plus FP16 convert costs for mixed).
/// Public so benches can report the per-class op-mix cost of an adaptive
/// frame next to the realized `ctu_prs_by_class` counts.
pub fn pr_pj(p: &EnergyParams, prec: Precision) -> f64 {
    match prec {
        Precision::Fp32 => 20.0 * p.fp32_mul_pj + 12.0 * p.fp32_add_pj,
        Precision::Fp16 => 20.0 * p.fp16_mul_pj + 12.0 * p.fp16_add_pj,
        Precision::Fp8 => 20.0 * p.fp8_mul_pj + 12.0 * p.fp8_add_pj,
        // Mixed: 4 FP16 subs (line 1) + FP8 mul stage + FP16 accumulation.
        Precision::Mixed => {
            4.0 * p.fp16_add_pj + 16.0 * p.fp8_mul_pj + 8.0 * p.fp16_add_pj + 4.0 * p.fp8_add_pj
        }
    }
}

/// Compute the frame energy from workload counters + pipeline occupancy.
pub fn frame_energy(
    wl: &FrameWorkload,
    hw: &HwConfig,
    total_cycles: u64,
    dram_bytes: u64,
    p: &EnergyParams,
) -> EnergyReport {
    let mut e = EnergyReport::default();

    // VRUs: actual per-pixel blends + the wasted evaluations on masked-in
    // pixels that failed the α test (they still occupy the lane).
    let vru_evals = wl.minitile_pairs * 16;
    e.vru_uj = vru_evals as f64 * blend_pair_pj(p) * 1e-6;

    // CTU: PRs priced per precision class + shared ln(255·o) term per job.
    // Global workloads fill exactly one `ctu_prs_by_class` bucket, and the
    // zero buckets contribute exactly 0.0 to the fold, so single-class
    // pricing is bit-identical to the historical `ctu_prs × pr_pj(tier)`.
    // PRs a hand-built workload never classed (counters set, buckets left
    // zero) are priced at the configured tier as before.
    if hw.ctu {
        let jobs = wl.dense_jobs + wl.sparse_jobs;
        let classed: u64 = wl.ctu_prs_by_class.iter().sum();
        let mut prs_pj = 0.0f64;
        for c in CLASSES {
            prs_pj += wl.ctu_prs_by_class[class_index(c)] as f64 * pr_pj(p, c);
        }
        prs_pj += wl.ctu_prs.saturating_sub(classed) as f64 * pr_pj(p, hw.cat_precision);
        e.ctu_uj = (prs_pj + jobs as f64 * (2.0 * p.fp16_mul_pj)) * 1e-6;
    }

    // Feature FIFOs: one push + one pop per (job, masked channel); a feature
    // record is ~8 words (μ′, conic, color, opacity, depth).
    let fifo_words = wl.minitile_pairs * 2 * 8;
    e.fifo_uj = fifo_words as f64 * p.sram_word_pj * 1e-6;

    // Preprocessing: projection (~60 FP32 mul-equivalents per visible
    // Gaussian) + sub-tile tests (~8 mul-eq per stage-1 pair; OBB ≈ 2×).
    let st_cost = match hw.subtile_test {
        super::SubtileTest::None => 0.0,
        super::SubtileTest::Aabb => 8.0,
        super::SubtileTest::Obb => 16.0,
    };
    e.preprocess_uj = (wl.visible_splats as f64 * 60.0 * p.fp32_mul_pj
        + wl.stage1_pairs as f64 * st_cost * p.fp32_mul_pj)
        * 1e-6;

    e.dram_uj = dram_bytes as f64 * p.dram_byte_pj * 1e-6;

    // Static: proportional to active units × cycles. VRU-equivalents:
    // VRUs + CTU (≈ 0.1 VRU each per Table II) + front-end (~4).
    let units = hw.total_vrus() as f64
        + if hw.ctu { hw.rendering_cores as f64 * 0.8 } else { 0.0 }
        + 4.0;
    // Datapath leakage + board/system floor over the frame duration.
    let frame_s = total_cycles as f64 / (hw.freq_ghz * 1e9);
    e.static_uj = total_cycles as f64 * units * p.static_unit_cycle_pj * 1e-6
        + frame_s * p.system_w * 1e6;

    e
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::camera::{Camera, Intrinsics};
    use crate::numeric::linalg::v3;
    use crate::scene::synthetic::{generate_scaled, preset};
    use crate::sim::workload::extract;

    fn wl(hw: &HwConfig) -> FrameWorkload {
        let scene = generate_scaled(&preset("garden"), 0.01);
        let cam = Camera::look_at(
            Intrinsics::from_fov(128, 128, 1.2),
            v3(0.0, 2.5, -12.0),
            v3(0.0, 0.5, 0.0),
            v3(0.0, 1.0, 0.0),
        );
        extract(&scene, &cam, hw)
    }

    #[test]
    fn totals_are_positive_and_sum() {
        let hw = HwConfig::flicker32();
        let w = wl(&hw);
        let e = frame_energy(&w, &hw, 100_000, 1_000_000, &EnergyParams::default());
        assert!(e.vru_uj > 0.0);
        assert!(e.ctu_uj > 0.0);
        assert!(e.dram_uj > 0.0);
        let sum = e.vru_uj + e.ctu_uj + e.fifo_uj + e.preprocess_uj + e.dram_uj + e.static_uj;
        assert!((e.total_uj() - sum).abs() < 1e-12);
    }

    #[test]
    fn ctu_saves_more_vru_energy_than_it_costs() {
        // The core energy claim of Fig. 8(b): CAT's own energy ≪ the blend
        // energy it eliminates.
        let p = EnergyParams::default();
        let hw_ctu = HwConfig::flicker32();
        let hw_no = HwConfig::simplified32();
        let w_ctu = wl(&hw_ctu);
        let w_no = wl(&hw_no);
        let e_ctu = frame_energy(&w_ctu, &hw_ctu, 0, 0, &p);
        let e_no = frame_energy(&w_no, &hw_no, 0, 0, &p);
        let saved = e_no.vru_uj - e_ctu.vru_uj;
        assert!(
            e_ctu.ctu_uj < saved * 0.5,
            "CTU {} µJ vs saved {} µJ",
            e_ctu.ctu_uj,
            saved
        );
        assert!(e_ctu.total_uj() < e_no.total_uj());
    }

    #[test]
    fn mixed_precision_cheaper_than_fp32_ctu() {
        let p = EnergyParams::default();
        assert!(pr_pj(&p, Precision::Mixed) < pr_pj(&p, Precision::Fp16));
        assert!(pr_pj(&p, Precision::Fp16) < pr_pj(&p, Precision::Fp32));
        assert!(pr_pj(&p, Precision::Fp8) < pr_pj(&p, Precision::Mixed));
    }

    #[test]
    fn classed_ctu_pricing_is_single_bucket_compatible() {
        let p = EnergyParams::default();
        let hw = HwConfig::flicker32();
        let w = wl(&hw);
        let classed = frame_energy(&w, &hw, 0, 0, &p);
        // A legacy workload (counters set, class buckets empty) prices at
        // the configured tier — which must equal the classed global price.
        let mut legacy = w.clone();
        legacy.ctu_prs_by_class = [0; 4];
        let legacy_e = frame_energy(&legacy, &hw, 0, 0, &p);
        assert_eq!(classed.ctu_uj.to_bits(), legacy_e.ctu_uj.to_bits());
        // Re-classing PRs from the mixed tier up to fp32 raises CTU energy.
        let mut promoted = w.clone();
        let i_mixed = class_index(Precision::Mixed);
        let i_fp32 = class_index(Precision::Fp32);
        let moved = promoted.ctu_prs_by_class[i_mixed] / 2;
        assert!(moved > 0, "flicker32 workload should have mixed-tier PRs");
        promoted.ctu_prs_by_class[i_mixed] -= moved;
        promoted.ctu_prs_by_class[i_fp32] += moved;
        let promoted_e = frame_energy(&promoted, &hw, 0, 0, &p);
        assert!(promoted_e.ctu_uj > classed.ctu_uj);
    }

    #[test]
    fn dram_energy_scales_with_bytes() {
        let hw = HwConfig::flicker32();
        let w = wl(&hw);
        let p = EnergyParams::default();
        let e1 = frame_energy(&w, &hw, 0, 1_000_000, &p);
        let e2 = frame_energy(&w, &hw, 0, 2_000_000, &p);
        assert!((e2.dram_uj / e1.dram_uj - 2.0).abs() < 1e-9);
    }
}
