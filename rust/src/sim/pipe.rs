//! Cycle-level pipeline model of one sub-tile complex (paper Fig. 5/6):
//! CTU (or plain dispatcher) → 4 feature FIFOs → 4 VRU channels, with the
//! stall-resilient backpressure protocol of Sec. IV-B.
//!
//! Timing rules (1 job = one Gaussian for one sub-tile):
//! * CTU occupancy: `ctu_cycles` per job (1 sparse / 2 dense). Without CTU
//!   the dispatcher issues 1 job/cycle.
//! * A completed job enqueues into **all** masked-in channel FIFOs
//!   atomically; if any target FIFO is full the result waits in the CTU's
//!   built-in FIFO. When that fills, the CTU halts intake (stall).
//! * A channel pops one job per `blend_cycles` (16 px / VRUs). Once its
//!   mini-tile has saturated (early termination), remaining pops cost one
//!   cycle each (transmittance check, no blend).
//!
//! Pops happen before pushes within a cycle, so a full FIFO frees a slot the
//! same cycle its channel finishes — matching a same-edge SRAM FIFO.

use super::workload::SubtileStream;

/// Per-complex cycle statistics.
#[derive(Clone, Copy, Debug, Default)]
pub struct PipeStats {
    /// Total cycles to drain the complex.
    pub cycles: u64,
    /// Cycles the CTU (or dispatcher) was processing a job.
    pub ctu_busy: u64,
    /// Cycles the CTU was halted on backpressure (paper Fig. 9 stall rate).
    pub ctu_stalled: u64,
    /// Σ over channels of cycles spent blending.
    pub vru_busy: u64,
    /// Σ over channels of cycles spent discarding post-saturation jobs.
    pub vru_discard: u64,
    /// Jobs fully filtered by the CTU (mask 0) — never reached a FIFO.
    pub filtered_jobs: u64,
    /// Peak FIFO occupancy observed (validates the Fig. 9 depth choice).
    pub peak_fifo: u32,
}

impl PipeStats {
    /// CTU stall rate as plotted in Fig. 9.
    pub fn stall_rate(&self) -> f64 {
        self.ctu_stalled as f64 / (self.ctu_busy + self.ctu_stalled).max(1) as f64
    }

    /// Merge a parallel complex: cycles take the max (complexes run
    /// side-by-side), busy/stall counters sum.
    pub fn merge_max_cycles(&mut self, o: &PipeStats) {
        self.cycles = self.cycles.max(o.cycles);
        self.ctu_busy += o.ctu_busy;
        self.ctu_stalled += o.ctu_stalled;
        self.vru_busy += o.vru_busy;
        self.vru_discard += o.vru_discard;
        self.filtered_jobs += o.filtered_jobs;
        self.peak_fifo = self.peak_fifo.max(o.peak_fifo);
    }
}

/// Simulate one sub-tile complex over its job stream.
///
/// `fifo_depth` — feature FIFO capacity per channel; `ctu_fifo_depth` — the
/// CTU's built-in output FIFO; `blend_cycles` — per-job channel occupancy.
pub fn run_subtile(
    stream: &SubtileStream,
    fifo_depth: usize,
    ctu_fifo_depth: usize,
    blend_cycles: u32,
) -> PipeStats {
    let mut stats = PipeStats::default();
    if stream.jobs.is_empty() {
        return stats;
    }

    // Channel state: FIFO occupancy (queue of job ordinals is unnecessary —
    // only counts and saturation ordinals matter), busy countdown, and how
    // many masked-in jobs each channel has consumed so far.
    #[derive(Default, Clone, Copy)]
    struct Channel {
        fifo: u32,
        busy: u32,
        consumed: u32,
    }
    let mut ch = [Channel::default(); 4];

    // CTU state.
    let mut next_job = 0usize; // index into stream.jobs
    let mut ctu_remaining = 0u32; // cycles left on current job
    let mut ctu_out: Vec<u8> = Vec::new(); // built-in FIFO of completed masks
    let mut cur_mask: Option<u8> = None; // job being processed

    let n = stream.jobs.len();
    // Safety bound: every job ≤ (ctu + 4 × blend) cycles plus drain.
    let bound = (n as u64 + 8) * (blend_cycles as u64 * 4 + 4) + 1024;

    loop {
        if next_job >= n
            && cur_mask.is_none()
            && ctu_out.is_empty()
            && ch.iter().all(|c| c.fifo == 0 && c.busy == 0)
        {
            break;
        }
        stats.cycles += 1;
        assert!(stats.cycles < bound, "pipe livelock: {stats:?}");

        // 1. Channels: advance blending; pop when idle.
        for (m, c) in ch.iter_mut().enumerate() {
            if c.busy > 0 {
                c.busy -= 1;
            }
            if c.busy == 0 && c.fifo > 0 {
                c.fifo -= 1;
                c.consumed += 1;
                if c.consumed <= stream.sat[m] {
                    c.busy = blend_cycles;
                    stats.vru_busy += blend_cycles as u64;
                } else {
                    // Post-saturation: transmittance check + drop, 1 cycle.
                    c.busy = 1;
                    stats.vru_discard += 1;
                }
            }
        }

        // 2. CTU output stage: drain the built-in FIFO into channel FIFOs.
        while let Some(&mask) = ctu_out.first() {
            let targets: Vec<usize> = (0..4).filter(|&m| mask & (1 << m) != 0).collect();
            let room = targets
                .iter()
                .all(|&m| (ch[m].fifo as usize) < fifo_depth);
            if !room {
                break;
            }
            for &m in &targets {
                ch[m].fifo += 1;
                stats.peak_fifo = stats.peak_fifo.max(ch[m].fifo);
            }
            ctu_out.remove(0);
        }

        // 3. CTU compute stage.
        if cur_mask.is_none() && next_job < n {
            // Intake halts when the built-in FIFO is full (stall signal).
            if ctu_out.len() < ctu_fifo_depth {
                let job = stream.jobs[next_job];
                next_job += 1;
                ctu_remaining = job.ctu_cycles as u32;
                cur_mask = Some(job.mask);
            } else {
                stats.ctu_stalled += 1;
            }
        }
        if let Some(mask) = cur_mask {
            stats.ctu_busy += 1;
            ctu_remaining -= 1;
            if ctu_remaining == 0 {
                if mask == 0 {
                    stats.filtered_jobs += 1;
                } else {
                    ctu_out.push(mask);
                }
                cur_mask = None;
            }
        }
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::workload::{GaussianJob, SubtileStream};

    fn stream(jobs: Vec<GaussianJob>, sat: [u32; 4]) -> SubtileStream {
        SubtileStream { jobs, sat }
    }

    fn job(ctu: u8, mask: u8) -> GaussianJob {
        GaussianJob {
            ctu_cycles: ctu,
            mask,
        }
    }

    #[test]
    fn empty_stream_zero_cycles() {
        let s = stream(vec![], [0; 4]);
        let st = run_subtile(&s, 16, 4, 8);
        assert_eq!(st.cycles, 0);
    }

    #[test]
    fn single_job_latency() {
        // 1 CTU cycle + 8 blend cycles, plus the pipeline handoff cycle.
        let s = stream(vec![job(1, 0b0001)], [1, 0, 0, 0]);
        let st = run_subtile(&s, 16, 4, 8);
        assert!(st.cycles >= 9 && st.cycles <= 11, "cycles {}", st.cycles);
        assert_eq!(st.vru_busy, 8);
        assert_eq!(st.ctu_busy, 1);
        assert_eq!(st.ctu_stalled, 0);
    }

    #[test]
    fn filtered_jobs_never_touch_fifos() {
        let s = stream(vec![job(1, 0), job(2, 0), job(1, 0)], [0; 4]);
        let st = run_subtile(&s, 16, 4, 8);
        assert_eq!(st.filtered_jobs, 3);
        assert_eq!(st.vru_busy, 0);
        assert_eq!(st.peak_fifo, 0);
        assert_eq!(st.ctu_busy, 4); // 1+2+1
    }

    #[test]
    fn throughput_bound_by_vru_when_all_pass() {
        // 50 jobs all hitting one channel: steady state = 8 cycles/job.
        let jobs: Vec<_> = (0..50).map(|_| job(1, 0b0001)).collect();
        let s = stream(jobs, [50, 0, 0, 0]);
        let st = run_subtile(&s, 16, 4, 8);
        assert!(
            (st.cycles as i64 - 50 * 8).unsigned_abs() < 24,
            "cycles {}",
            st.cycles
        );
    }

    #[test]
    fn throughput_bound_by_ctu_when_filtered() {
        // Dense jobs (2 cycles) all filtered: pure CTU throughput.
        let jobs: Vec<_> = (0..50).map(|_| job(2, 0)).collect();
        let s = stream(jobs, [0; 4]);
        let st = run_subtile(&s, 16, 4, 8);
        assert!(
            (st.cycles as i64 - 100).unsigned_abs() < 8,
            "cycles {}",
            st.cycles
        );
    }

    #[test]
    fn shallow_fifo_stalls_deep_fifo_doesnt() {
        // Bursty: all four channels loaded, CTU far faster than VRUs.
        let jobs: Vec<_> = (0..64).map(|_| job(1, 0b1111)).collect();
        let shallow = run_subtile(&stream(jobs.clone(), [64; 4]), 1, 1, 8);
        let deep = run_subtile(&stream(jobs, [64; 4]), 128, 4, 8);
        assert!(shallow.ctu_stalled > 0, "shallow must stall");
        assert!(
            deep.ctu_stalled < shallow.ctu_stalled,
            "deep {} vs shallow {}",
            deep.ctu_stalled,
            shallow.ctu_stalled
        );
        // Total work identical.
        assert_eq!(shallow.vru_busy, deep.vru_busy);
    }

    #[test]
    fn deeper_fifo_never_slower() {
        let jobs: Vec<_> = (0..40)
            .map(|i| job(1 + (i % 2) as u8, 0b0011 | ((i % 4) as u8) << 2))
            .collect();
        let mut prev = u64::MAX;
        for depth in [1usize, 2, 4, 8, 16, 32] {
            let st = run_subtile(&stream(jobs.clone(), [40; 4]), depth, 4, 8);
            assert!(st.cycles <= prev, "depth {depth}: {} > {prev}", st.cycles);
            prev = st.cycles;
        }
    }

    #[test]
    fn saturation_discards_cheaply() {
        // Channel 0 saturates after 2 jobs; the rest of 30 jobs cost 1 cycle.
        let jobs: Vec<_> = (0..30).map(|_| job(1, 0b0001)).collect();
        let st = run_subtile(&stream(jobs, [2, 0, 0, 0]), 16, 4, 8);
        assert_eq!(st.vru_busy, 16); // 2 × 8
        assert_eq!(st.vru_discard, 28);
        assert!(st.cycles < 2 * 8 + 28 + 10, "cycles {}", st.cycles);
    }

    #[test]
    fn peak_fifo_bounded_by_depth() {
        let jobs: Vec<_> = (0..100).map(|_| job(1, 0b1111)).collect();
        for depth in [1usize, 3, 7] {
            let st = run_subtile(&stream(jobs.clone(), [100; 4]), depth, 4, 8);
            assert!(st.peak_fifo as usize <= depth, "depth {depth}");
        }
    }

    #[test]
    fn work_conservation_across_depths() {
        // vru_busy + vru_discard constant for any depth.
        let jobs: Vec<_> = (0..60)
            .map(|i| job(1, (0b0001 << (i % 4)) as u8))
            .collect();
        let base = run_subtile(&stream(jobs.clone(), [10, 10, 10, 10]), 128, 4, 8);
        for depth in [1usize, 2, 16] {
            let st = run_subtile(&stream(jobs.clone(), [10, 10, 10, 10]), depth, 4, 8);
            assert_eq!(st.vru_busy, base.vru_busy);
            assert_eq!(st.vru_discard, base.vru_discard);
        }
    }
}
