//! Area model (paper Table II): per-unit areas at TSMC 28 nm, calibrated so
//! the *shape* of the paper's breakdown holds — CTU < 10% of the rendering
//! cores, and FLICKER-32+CTU ≈ 14% smaller than the 64-VRU simplified
//! baseline. Absolute mm² are synthesis-grade estimates from published
//! 28 nm datapath/SRAM densities, not DC results.

use super::HwConfig;
use crate::cat::Precision;

/// Per-unit areas in mm² (28 nm).
#[derive(Clone, Copy, Debug)]
pub struct AreaParams {
    /// One VRU: FP16 Eq.-1 datapath + blend accumulators.
    pub vru_mm2: f64,
    /// Per-channel fixed logic (sequencer, transmittance check).
    pub channel_ctrl_mm2: f64,
    /// Feature-FIFO SRAM per entry (8×32-bit record) incl. periphery.
    pub fifo_entry_mm2: f64,
    /// One PRTU at FP32 (scales down with precision).
    pub prtu_fp32_mm2: f64,
    /// CTU control + MMU + shared-term unit.
    pub ctu_ctrl_mm2: f64,
    /// Sorting unit (per rendering core).
    pub sorter_mm2: f64,
    /// Preprocessing core (projection + cull + classify + sub-tile test).
    pub preprocess_mm2: f64,
    /// Feature buffers and misc SRAM per rendering core.
    pub corebuf_mm2: f64,
}

impl Default for AreaParams {
    fn default() -> Self {
        AreaParams {
            vru_mm2: 0.040,
            channel_ctrl_mm2: 0.010,
            fifo_entry_mm2: 0.00060,
            prtu_fp32_mm2: 0.060,
            ctu_ctrl_mm2: 0.012,
            sorter_mm2: 0.20,
            preprocess_mm2: 0.90,
            corebuf_mm2: 0.17,
        }
    }
}

/// PRTU scaling with datapath precision (multiplier area ∝ ~mantissa²;
/// mixed = FP16 front + FP8 quad-accumulate).
///
/// Public for adaptive-precision reporting: a chip that classes tiles at
/// runtime must still *provision* its PRTUs for the widest class it may
/// dispatch, so the area of an adaptive config is the ceiling
/// `prtu_scale(Fp32)` — only the energy model prices the realized
/// per-tile class mix (see `sim::energy`).
pub fn prtu_scale(p: Precision) -> f64 {
    match p {
        Precision::Fp32 => 1.0,
        Precision::Fp16 => 0.38,
        Precision::Mixed => 0.22,
        Precision::Fp8 => 0.14,
    }
}

/// Area breakdown for a config, in mm².
#[derive(Clone, Debug, Default)]
pub struct AreaReport {
    /// Volume rendering units.
    pub vru_mm2: f64,
    /// Feature FIFOs.
    pub fifo_mm2: f64,
    /// Contribution-aware test units.
    pub ctu_mm2: f64,
    /// Sorting units.
    pub sorter_mm2: f64,
    /// Preprocessing cores.
    pub preprocess_mm2: f64,
    /// On-chip buffers.
    pub buffers_mm2: f64,
}

impl AreaReport {
    /// Area of the rendering cores (VRUs + FIFOs + buffers).
    pub fn rendering_core_mm2(&self) -> f64 {
        self.vru_mm2 + self.fifo_mm2 + self.buffers_mm2
    }

    /// Total accelerator area.
    pub fn total_mm2(&self) -> f64 {
        self.vru_mm2 + self.fifo_mm2 + self.ctu_mm2 + self.sorter_mm2 + self.preprocess_mm2
            + self.buffers_mm2
    }

    /// Rows for the Table II printer: (component, mm², share).
    pub fn rows(&self) -> Vec<(&'static str, f64, f64)> {
        let total = self.total_mm2();
        vec![
            ("VRUs (rendering cores)", self.vru_mm2, self.vru_mm2 / total),
            ("Feature FIFOs", self.fifo_mm2, self.fifo_mm2 / total),
            ("CTUs", self.ctu_mm2, self.ctu_mm2 / total),
            ("Sorting units", self.sorter_mm2, self.sorter_mm2 / total),
            ("Preprocessing cores", self.preprocess_mm2, self.preprocess_mm2 / total),
            ("Core buffers", self.buffers_mm2, self.buffers_mm2 / total),
        ]
    }
}

/// Compute the area of a hardware config.
pub fn area(hw: &HwConfig, p: &AreaParams) -> AreaReport {
    let channels = (hw.rendering_cores * hw.channels_per_core) as f64;
    let mut r = AreaReport {
        vru_mm2: hw.total_vrus() as f64 * p.vru_mm2 + channels * p.channel_ctrl_mm2,
        fifo_mm2: channels * hw.fifo_depth as f64 * p.fifo_entry_mm2,
        sorter_mm2: hw.rendering_cores as f64 * p.sorter_mm2,
        preprocess_mm2: hw.rendering_cores as f64 * p.preprocess_mm2,
        buffers_mm2: hw.rendering_cores as f64 * p.corebuf_mm2,
        ..Default::default()
    };
    if hw.ctu {
        // One CTU per rendering core: 2 PRTUs + control, plus its built-in
        // stall FIFO.
        let prtu = p.prtu_fp32_mm2 * prtu_scale(hw.cat_precision);
        r.ctu_mm2 = hw.rendering_cores as f64
            * (2.0 * prtu + p.ctu_ctrl_mm2 + hw.ctu_fifo_depth as f64 * p.fifo_entry_mm2);
    }
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ctu_below_ten_percent_of_rendering_core() {
        // Paper: "the CTU occupies less than 10% of the VRUs area
        // (rendering core)".
        let r = area(&HwConfig::flicker32(), &AreaParams::default());
        let ratio = r.ctu_mm2 / r.rendering_core_mm2();
        assert!(ratio < 0.10, "CTU/core ratio {ratio}");
        assert!(ratio > 0.01, "CTU should not be negligible: {ratio}");
    }

    #[test]
    fn flicker_saves_vs_64vru_baseline() {
        // Paper Table II(b): ~14% total area saving vs the 64-VRU
        // simplified baseline.
        let p = AreaParams::default();
        let ours = area(&HwConfig::flicker32(), &p).total_mm2();
        let base = area(&HwConfig::simplified64(), &p).total_mm2();
        let saving = 1.0 - ours / base;
        assert!(
            (0.08..0.25).contains(&saving),
            "area saving {saving}, ours {ours} base {base}"
        );
    }

    #[test]
    fn mixed_precision_shrinks_ctu() {
        let p = AreaParams::default();
        let mixed = area(&HwConfig::flicker32(), &p).ctu_mm2;
        let fp32 = area(
            &HwConfig {
                cat_precision: Precision::Fp32,
                ..HwConfig::flicker32()
            },
            &p,
        )
        .ctu_mm2;
        assert!(mixed < fp32 * 0.5, "mixed {mixed} vs fp32 {fp32}");
    }

    #[test]
    fn fifo_area_scales_with_depth() {
        let p = AreaParams::default();
        let d16 = area(&HwConfig::flicker32(), &p).fifo_mm2;
        let d128 = area(
            &HwConfig {
                fifo_depth: 128,
                ..HwConfig::flicker32()
            },
            &p,
        )
        .fifo_mm2;
        assert!((d128 / d16 - 8.0).abs() < 1e-9);
    }

    #[test]
    fn rows_shares_sum_to_one() {
        let r = area(&HwConfig::flicker32(), &AreaParams::default());
        let s: f64 = r.rows().iter().map(|(_, _, share)| share).sum();
        assert!((s - 1.0).abs() < 1e-9);
    }

    #[test]
    fn no_ctu_means_zero_ctu_area() {
        let r = area(&HwConfig::gscore64(), &AreaParams::default());
        assert_eq!(r.ctu_mm2, 0.0);
    }
}
