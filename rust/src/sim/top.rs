//! Top-level simulator: ties workload extraction, the per-sub-tile cycle
//! model, DRAM timing, and the energy model into a frame-level report.
//!
//! Frame phases (paper Fig. 5): preprocessing + sorting run a tile ahead of
//! the rendering complex (double-buffered feature buffers), so frame time is
//! max(rendering-pipeline cycles, preprocessing cycles, DRAM transfer) plus
//! a small pipeline fill term.

use super::dram::{frame_traffic, transfer_seconds, ClusterInfo, DramTraffic};
use super::energy::{frame_energy, EnergyParams, EnergyReport};
use super::pipe::{run_subtile, PipeStats};
use super::workload::{extract, FrameWorkload};
use super::HwConfig;
use crate::camera::Camera;
use crate::scene::clustering::cluster;
use crate::scene::gaussian::Scene;

/// Full per-frame simulation report.
#[derive(Clone, Debug)]
pub struct SimReport {
    /// Hardware preset name.
    pub config: String,
    /// Rendering-stage cycles (the Fig. 8/9 quantity).
    pub render_cycles: u64,
    /// Preprocessing/sorting cycles (overlapped).
    pub preprocess_cycles: u64,
    /// Frame-level cycles after overlap.
    pub frame_cycles: u64,
    /// Frame time at the configured clock (ms).
    pub frame_ms: f64,
    /// Frames per second.
    pub fps: f64,
    /// Pipeline busy/stall counters.
    pub pipe: PipeStats,
    /// DRAM traffic breakdown.
    pub traffic: DramTraffic,
    /// Energy breakdown.
    pub energy: EnergyReport,
    /// The workload the simulation replayed.
    pub workload: FrameWorkload,
}

impl SimReport {
    /// Rendering-stage time in ms (ignores preprocessing/DRAM overlap).
    pub fn render_ms(&self, hw: &HwConfig) -> f64 {
        self.render_cycles as f64 / (hw.freq_ghz * 1e9) * 1e3
    }
}

/// Simulate one frame.
pub fn simulate_frame(scene: &Scene, cam: &Camera, hw: &HwConfig) -> SimReport {
    let wl = extract(scene, cam, hw);
    simulate_workload(scene, cam, hw, wl)
}

/// Simulate a frame from an already-extracted workload (lets sweeps reuse
/// the expensive functional pass when only pipe parameters change).
pub fn simulate_workload(
    scene: &Scene,
    cam: &Camera,
    hw: &HwConfig,
    wl: FrameWorkload,
) -> SimReport {
    // Rendering pipeline: the 4 sub-tile complexes of a tile run in
    // parallel; tiles are processed back-to-back.
    let mut pipe = PipeStats::default();
    let mut render_cycles: u64 = 0;
    let blend = hw.blend_cycles();
    for tile in &wl.tiles {
        let mut tile_stats = PipeStats::default();
        for st in &tile.subtiles {
            let s = run_subtile(st, hw.fifo_depth, hw.ctu_fifo_depth, blend);
            tile_stats.merge_max_cycles(&s);
        }
        render_cycles += tile_stats.cycles;
        pipe.ctu_busy += tile_stats.ctu_busy;
        pipe.ctu_stalled += tile_stats.ctu_stalled;
        pipe.vru_busy += tile_stats.vru_busy;
        pipe.vru_discard += tile_stats.vru_discard;
        pipe.filtered_jobs += tile_stats.filtered_jobs;
        pipe.peak_fifo = pipe.peak_fifo.max(tile_stats.peak_fifo);
    }
    pipe.cycles = render_cycles;

    // Preprocessing: projection ≈ 16 cycles/Gaussian on each of the 4
    // parallel preprocessing cores, plus 1 cycle per stage-1 test; sorting
    // ≈ n·log n / 4-lane merge network, overlapped.
    let proj = wl.visible_splats as u64 * 16 / 4;
    let tests = wl.stage1_pairs / 4;
    let nlogn = {
        let n = wl.tile_pairs.max(2) as f64;
        (n * n.log2() / 4.0) as u64
    };
    let preprocess_cycles = proj + tests + nlogn;

    // DRAM.
    let ci = if hw.clustering {
        let cl = cluster(scene, 32);
        Some(ClusterInfo {
            num_clusters: cl.num_clusters(),
            visible_clusters: cl.visible_clusters(cam),
            gaussians_in_visible: cl.cull(cam).len(),
        })
    } else {
        None
    };
    let traffic = frame_traffic(&wl, hw, ci);
    let dram_s = transfer_seconds(traffic.total(), hw);
    let dram_cycles = (dram_s * hw.freq_ghz * 1e9) as u64;

    // Fixed per-frame overhead: host kickoff, descriptor setup, pipeline
    // fill/drain (~30 µs at 1 GHz) — keeps tiny-workload comparisons sane.
    const FRAME_OVERHEAD_CYCLES: u64 = 30_000;
    let frame_cycles = render_cycles.max(preprocess_cycles).max(dram_cycles)
        + (preprocess_cycles.min(render_cycles) / wl.tiles.len().max(1) as u64)
        + FRAME_OVERHEAD_CYCLES;
    let frame_s = frame_cycles as f64 / (hw.freq_ghz * 1e9);

    let energy = frame_energy(&wl, hw, frame_cycles, traffic.total(), &EnergyParams::default());

    SimReport {
        config: hw.name.clone(),
        render_cycles,
        preprocess_cycles,
        frame_cycles,
        frame_ms: frame_s * 1e3,
        fps: 1.0 / frame_s,
        pipe,
        traffic,
        energy,
        workload: wl,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::camera::{Camera, Intrinsics};
    use crate::numeric::linalg::v3;
    use crate::scene::synthetic::{generate_scaled, preset};

    fn setup() -> (Scene, Camera) {
        let scene = generate_scaled(&preset("garden"), 0.01);
        let cam = Camera::look_at(
            Intrinsics::from_fov(128, 128, 1.2),
            v3(0.0, 2.5, -12.0),
            v3(0.0, 0.5, 0.0),
            v3(0.0, 1.0, 0.0),
        );
        (scene, cam)
    }

    #[test]
    fn report_is_consistent() {
        let (s, c) = setup();
        let r = simulate_frame(&s, &c, &HwConfig::flicker32());
        assert!(r.render_cycles > 0);
        assert!(r.frame_cycles >= r.render_cycles.min(r.preprocess_cycles));
        assert!(r.fps > 0.0);
        assert!((r.frame_ms * r.fps - 1000.0).abs() < 1.0);
        assert!(r.energy.total_uj() > 0.0);
    }

    #[test]
    fn ctu_speeds_up_rendering_stage() {
        // Fig. 8(a) mechanism: CTU cuts VRU work enough to beat the
        // simplified config even at equal VRU count.
        let (s, c) = setup();
        let ctu = simulate_frame(&s, &c, &HwConfig::flicker32());
        let plain = simulate_frame(&s, &c, &HwConfig::simplified32());
        let speedup = plain.render_cycles as f64 / ctu.render_cycles as f64;
        assert!(speedup > 1.5, "CTU speedup {speedup}");
    }

    #[test]
    fn flicker32_competitive_with_gscore64() {
        // Fig. 8: FLICKER with 32 VRUs ≈ GSCore with 64 VRUs.
        let (s, c) = setup();
        let f = simulate_frame(&s, &c, &HwConfig::flicker32());
        let g = simulate_frame(&s, &c, &HwConfig::gscore64());
        let ratio = g.render_cycles as f64 / f.render_cycles as f64;
        assert!(
            (0.6..2.5).contains(&ratio),
            "flicker-vs-gscore ratio {ratio}"
        );
    }

    #[test]
    fn flicker_more_energy_efficient_than_gscore() {
        let (s, c) = setup();
        let f = simulate_frame(&s, &c, &HwConfig::flicker32());
        let g = simulate_frame(&s, &c, &HwConfig::gscore64());
        assert!(
            f.energy.total_uj() < g.energy.total_uj(),
            "flicker {} µJ vs gscore {} µJ",
            f.energy.total_uj(),
            g.energy.total_uj()
        );
    }

    #[test]
    fn deeper_fifo_not_slower() {
        let (s, c) = setup();
        let mut prev: Option<u64> = None;
        for depth in [1usize, 4, 16, 64] {
            let hw = HwConfig {
                fifo_depth: depth,
                ..HwConfig::flicker32()
            };
            let r = simulate_frame(&s, &c, &hw);
            if let Some(p) = prev {
                assert!(
                    r.render_cycles <= p + p / 50,
                    "depth {depth}: {} vs {p}",
                    r.render_cycles
                );
            }
            prev = Some(r.render_cycles);
        }
    }

    #[test]
    fn stall_rate_decreases_with_depth() {
        let (s, c) = setup();
        let shallow = simulate_frame(
            &s,
            &c,
            &HwConfig {
                fifo_depth: 1,
                ..HwConfig::flicker32()
            },
        );
        let deep = simulate_frame(
            &s,
            &c,
            &HwConfig {
                fifo_depth: 64,
                ..HwConfig::flicker32()
            },
        );
        assert!(shallow.pipe.stall_rate() >= deep.pipe.stall_rate());
    }
}
