//! Frame workload extraction: turns a (scene, camera, config) triple into
//! the per-tile, per-sub-tile Gaussian streams the cycle model consumes.
//!
//! This is the *functional* half of the simulator: it runs projection, tile
//! binning, depth sorting, Stage-1 sub-tile tests, Mini-Tile CAT, and a
//! per-mini-tile transmittance sweep that determines where early termination
//! fires. The cycle model (`pipe`) then replays these streams against FIFO /
//! CTU / VRU timing.

use super::{HwConfig, SubtileTest};
use crate::camera::Camera;
use crate::cat::{CatConfig, CatEngine};
use crate::render::plan::FramePlan;
use crate::render::precision::{class_index, TileClassMap};
use crate::render::project::{Splat, ALPHA_MIN};
use crate::render::pyramid::TilePyramid;
use crate::render::raster::{RenderOptions, MINITILE};
use crate::render::tile::{intersects_aabb, intersects_obb, Rect, Strategy};
use crate::scene::gaussian::Scene;

/// One Gaussian's entry in a sub-tile stream.
#[derive(Clone, Copy, Debug)]
pub struct GaussianJob {
    /// CTU occupancy in cycles: 1 (sparse: 2 PRs on 2 PRTUs) or 2 (dense:
    /// 4 PRs in two batches). Without a CTU, dispatch takes 1 cycle.
    pub ctu_cycles: u8,
    /// 4-bit mini-tile mask within the sub-tile (output of Stage 2, or all
    /// ones for non-CTU configs).
    pub mask: u8,
}

/// Stream of jobs for one sub-tile complex, plus per-mini-tile saturation
/// ordinals: `sat[m]` = number of *masked-in* jobs mini-tile `m` consumes
/// before all its pixels saturate (jobs after that are popped & discarded).
#[derive(Clone, Debug, Default)]
pub struct SubtileStream {
    /// The depth-ordered jobs for this complex.
    pub jobs: Vec<GaussianJob>,
    /// Saturation ordinal per mini-tile.
    pub sat: [u32; 4],
}

/// Workload for one 16×16 tile: one stream per sub-tile complex.
#[derive(Clone, Debug, Default)]
pub struct TileWork {
    /// Streams for the four 8×8 sub-tile complexes.
    pub subtiles: [SubtileStream; 4],
}

/// Whole-frame workload plus the aggregate counters the DRAM/energy models
/// and Fig. 4 need.
#[derive(Clone, Debug, Default)]
pub struct FrameWorkload {
    /// Per-tile job streams.
    pub tiles: Vec<TileWork>,
    /// Gaussians in the scene (DRAM: metadata universe).
    pub scene_gaussians: usize,
    /// Splats surviving frustum culling + projection.
    pub visible_splats: usize,
    /// Σ tile-list lengths (tile-level duplicates).
    pub tile_pairs: usize,
    /// (gaussian, sub-tile) pairs offered to Stage 1.
    pub stage1_pairs: u64,
    /// Pairs surviving Stage 1 (CTU input).
    pub stage2_pairs: u64,
    /// (gaussian, mini-tile) pairs surviving CAT (VRU input).
    pub minitile_pairs: u64,
    /// Σ CTU PRs evaluated (mixed-precision datapath activations).
    pub ctu_prs: u64,
    /// `ctu_prs` split by the precision class that evaluated them, indexed
    /// by [`class_index`] ([Fp32, Fp16, Mixed, Fp8]). Global-precision
    /// plans put everything in the configured tier's bucket; adaptive
    /// plans spread PRs across the realized per-tile class mix, which is
    /// what the energy model prices per class.
    pub ctu_prs_by_class: [u64; 4],
    /// Dense/sparse split of CTU jobs.
    pub dense_jobs: u64,
    /// Sparse-sampled CTU jobs.
    pub sparse_jobs: u64,
    /// Per-pixel blends actually performed (energy model).
    pub blended_pairs: u64,
    /// (tile, splat) pairs surviving the plan's coarse gate
    /// (`render::pyramid`); equals `tile_pairs` when the gate is off.
    pub splats_submitted: u64,
    /// Pairs the whole-tile gate removed — they never generate sub-tile
    /// (Stage 1 / CTU / VRU) traffic downstream.
    pub gate_tile_rejected: u64,
    /// (quadrant, splat) pairs the level-2 gate removed; their sub-tiles
    /// are skipped before Stage 1.
    pub gate_quad_rejected: u64,
    /// Frame width (pixels).
    pub width: u32,
    /// Frame height (pixels).
    pub height: u32,
}

impl FrameWorkload {
    /// Average Gaussians processed per pixel (Fig. 4 metric): every
    /// mini-tile job costs its 16 pixels one Eq.-1 evaluation each.
    pub fn per_pixel_processed(&self) -> f64 {
        (self.minitile_pairs * 16) as f64 / (self.width as u64 * self.height as u64) as f64
    }

    /// Fold another frame's workload into this one — the aggregate view a
    /// multi-tenant drain produces (many clients' frames, one accelerator).
    /// Counters sum, tile streams concatenate, and the merged trace models
    /// a virtual frame stacked vertically (`height` accumulates), so
    /// [`per_pixel_processed`](Self::per_pixel_processed) stays the
    /// work-per-rendered-pixel average across every absorbed frame.
    /// `scene_gaussians` takes the max, not the sum: service clients share
    /// one scene store, so the metadata universe does not grow per frame.
    ///
    /// # Panics
    /// If the frames' widths differ (the stacked-frame model needs one
    /// width; the service's synthetic workloads share intrinsics).
    pub fn absorb(&mut self, other: &FrameWorkload) {
        assert_eq!(self.width, other.width, "workload absorb: width mismatch");
        self.tiles.extend(other.tiles.iter().cloned());
        self.scene_gaussians = self.scene_gaussians.max(other.scene_gaussians);
        self.visible_splats += other.visible_splats;
        self.tile_pairs += other.tile_pairs;
        self.stage1_pairs += other.stage1_pairs;
        self.stage2_pairs += other.stage2_pairs;
        self.minitile_pairs += other.minitile_pairs;
        self.ctu_prs += other.ctu_prs;
        for (acc, x) in self.ctu_prs_by_class.iter_mut().zip(other.ctu_prs_by_class) {
            *acc += x;
        }
        self.dense_jobs += other.dense_jobs;
        self.sparse_jobs += other.sparse_jobs;
        self.blended_pairs += other.blended_pairs;
        self.splats_submitted += other.splats_submitted;
        self.gate_tile_rejected += other.gate_tile_rejected;
        self.gate_quad_rejected += other.gate_quad_rejected;
        self.height += other.height;
    }
}

/// Extract the frame workload for a hardware config. Builds a fresh
/// [`FramePlan`] (16×16 AABB tiling, the paper's fixed configuration) and
/// delegates to [`extract_from_plan`] — callers that already hold a plan
/// for this view (a `coordinator::Session`'s cached `session.plan(i)`, or
/// a view just rendered) should call that directly.
pub fn extract(scene: &Scene, cam: &Camera, hw: &HwConfig) -> FrameWorkload {
    let plan = FramePlan::build(scene, cam, &RenderOptions::default());
    extract_from_plan(scene, &plan, hw)
}

/// Workload trace for a view that may have a cheaply reachable prepared
/// plan: when `opts` matches the extractor's fixed 16×16 AABB geometry,
/// the plan is obtained from the (lazy) `plan` thunk and reused via
/// [`extract_from_plan`]; otherwise a fresh default-geometry [`extract`]
/// runs and the thunk is never called — so a `coordinator::Session` with
/// incompatible options does not build (or fetch) a plan just to have it
/// rejected. This is the one place that knows the compatibility rule;
/// callers (the CLI, examples) go through here instead of re-encoding it.
pub fn extract_for<'a>(
    scene: &Scene,
    cam: &Camera,
    opts: &RenderOptions,
    plan: impl FnOnce() -> &'a FramePlan,
    hw: &HwConfig,
) -> FrameWorkload {
    if opts.tile_size == 16 && opts.strategy == Strategy::Aabb {
        extract_from_plan(scene, plan(), hw)
    } else {
        extract(scene, cam, hw)
    }
}

/// Extract the frame workload from a prebuilt [`FramePlan`] — projection,
/// tile binning, and depth sorting are reused from the plan instead of
/// re-derived, so a view that was just rendered can be simulated without
/// repeating its frame preparation.
///
/// # Panics
///
/// The sub-tile/mini-tile sweep below hard-codes the paper's fixed
/// geometry (16×16 AABB tiles split into 8×8 sub-tiles of 4×4
/// mini-tiles), so plans built with any other `tile_size`/`strategy` are
/// rejected rather than silently miscounted.
pub fn extract_from_plan(scene: &Scene, plan: &FramePlan, hw: &HwConfig) -> FrameWorkload {
    assert!(
        plan.grid.tile == 16 && plan.opts.strategy == Strategy::Aabb,
        "workload extraction assumes the paper's 16×16 AABB tiling \
         (got tile_size {} / {:?})",
        plan.grid.tile,
        plan.opts.strategy
    );
    let (splats, grid, lists) = (&plan.splats, &plan.grid, &plan.lists);
    let mut wl = FrameWorkload {
        scene_gaussians: scene.len(),
        visible_splats: splats.len(),
        tile_pairs: lists.iter().map(|l| l.len()).sum(),
        width: grid.width,
        height: grid.height,
        ..Default::default()
    };
    let mut cat = CatEngine::new(CatConfig {
        mode: hw.cat_mode,
        precision: hw.cat_precision,
        stage1: false, // stage 1 handled explicitly below
    });
    // Adaptive plans class each tile; the CTU then evaluates that tile's
    // PRs at the class precision instead of `hw.cat_precision`. The
    // engine's one-entry PreQuant cache is keyed on splat id only, so a
    // classed tile gets its own engine — reusing `cat` across precision
    // changes would serve operands quantized for the wrong scheme.
    // Rect plans refine mid/high-energy tiles per quadrant: each sub-tile
    // complex (sub-tile index == quadrant bit) runs its quadrant's class
    // and its PRs land in that class's bucket — the quadrant-weighted CTU
    // accounting the energy model prices.
    let classes = plan.tile_classes();
    let rect_maps = plan.tile_rect_classes();

    wl.tiles.reserve(lists.len());
    // Per-mini-tile transmittance state, reset per tile.
    let mut trans; // [minitile 0..16][pixel 0..16]
    let mut done;

    for (t, list) in lists.iter().enumerate() {
        let rect = grid.rect(t);
        // The plan's coarse gate, when on, removes (tile, splat) and
        // (quadrant, splat) pairs before any sub-tile traffic — the cycle
        // and DRAM/energy models then see the reduced streams, matching
        // what the gated rasterizer executes.
        let pyramid = if plan.opts.gate.active() {
            Some(TilePyramid::new(&rect, grid.tile))
        } else {
            None
        };
        let map = rect_maps.as_ref().map(|m| m[t]);
        let class = match map {
            // Uniform rect tiles behave exactly like per-tile classed ones.
            Some(m) => m.uniform(),
            None => classes.as_ref().map(|c| c[t]),
        };
        let mut tile_cat = class.map(|precision| {
            CatEngine::new(CatConfig {
                mode: hw.cat_mode,
                precision,
                stage1: false,
            })
        });
        // Mixed rect tiles: one engine per quadrant at its class (the
        // PreQuant cache is precision-specific, so quadrants never share).
        let mut quad_cat: Option<[CatEngine; 4]> = match map {
            Some(TileClassMap::Mixed(quads)) => Some(std::array::from_fn(|q| {
                CatEngine::new(CatConfig {
                    mode: hw.cat_mode,
                    precision: quads[q],
                    stage1: false,
                })
            })),
            _ => None,
        };
        let class_bucket = class_index(class.unwrap_or(hw.cat_precision));
        let mut tile = TileWork::default();
        trans = [[1.0f32; 16]; 16];
        done = [false; 16];

        for &si in list {
            let s = &splats[si as usize];
            let quad_live = match &pyramid {
                Some(pyr) => {
                    let d = pyr.gate(s, &plan.opts.gate);
                    if d.tile_rejected {
                        wl.gate_tile_rejected += 1;
                        continue;
                    }
                    wl.splats_submitted += 1;
                    wl.gate_quad_rejected += d.quads_rejected as u64;
                    d.quad_mask
                }
                None => {
                    wl.splats_submitted += 1;
                    0xF
                }
            };
            for (sub_idx, sub) in subtile_rects(&rect).iter().enumerate() {
                // Gate level 2: dead quadrants produce no Stage-1 pairs
                // (sub-tile index == quadrant bit, both [TL, TR, BL, BR]).
                if quad_live & (1 << sub_idx) == 0 {
                    continue;
                }
                wl.stage1_pairs += 1;
                let pass1 = match hw.subtile_test {
                    SubtileTest::None => true,
                    SubtileTest::Aabb => intersects_aabb(s, sub),
                    SubtileTest::Obb => intersects_obb(s, sub),
                };
                if !pass1 {
                    continue;
                }
                wl.stage2_pairs += 1;

                let (mask, ctu_cycles) = if hw.ctu {
                    let eng = match &mut quad_cat {
                        Some(qc) => &mut qc[sub_idx],
                        None => tile_cat.as_mut().unwrap_or(&mut cat),
                    };
                    let prs = eng.prs_for(s);
                    let m = eng.subtile_mask(sub, s);
                    if prs == 4 {
                        wl.dense_jobs += 1;
                    } else {
                        wl.sparse_jobs += 1;
                    }
                    let bucket = match map {
                        Some(m) => class_index(m.quad(sub_idx)),
                        None => class_bucket,
                    };
                    wl.ctu_prs += prs as u64;
                    wl.ctu_prs_by_class[bucket] += prs as u64;
                    (m, (prs as u8).div_ceil(2))
                } else {
                    (0xF, 1)
                };
                if mask == 0 {
                    // CTU filtered it entirely: occupies the CTU but never
                    // reaches a FIFO.
                    tile.subtiles[sub_idx].jobs.push(GaussianJob {
                        ctu_cycles,
                        mask: 0,
                    });
                    continue;
                }
                wl.minitile_pairs += mask.count_ones() as u64;

                // Functional per-mini-tile transmittance sweep for
                // saturation ordinals + blend-energy accounting.
                // §Perf: hoisted conic locals + Eq.-2 threshold skip the
                // exp() for sub-threshold pixels (same trick as raster.rs).
                let (ca, cb, cc) = (s.conic.a, s.conic.b, s.conic.c);
                let (mx, my) = (s.mean.x, s.mean.y);
                let e_max = (255.0 * s.opacity).max(1e-12).ln();
                for m in 0..4usize {
                    if mask & (1 << m) == 0 {
                        continue;
                    }
                    let g_mt = sub_idx * 4 + m;
                    if done[g_mt] {
                        continue;
                    }
                    let mt_x = sub.x0 + (m % 2) as f32 * MINITILE as f32;
                    let mt_y = sub.y0 + (m / 2) as f32 * MINITILE as f32;
                    let mut all_sat = true;
                    for py in 0..MINITILE {
                        let dy = mt_y + py as f32 + 0.5 - my;
                        let half_cc_dy2 = 0.5 * cc * dy * dy;
                        let cb_dy = cb * dy;
                        for px in 0..MINITILE {
                            let pi = (py * MINITILE + px) as usize;
                            let tcur = trans[g_mt][pi];
                            if tcur < 1e-4 {
                                continue;
                            }
                            let dx = mt_x + px as f32 + 0.5 - mx;
                            let e = 0.5 * ca * dx * dx + half_cc_dy2 + cb_dy * dx;
                            if e < e_max && e >= 0.0 {
                                let a = (s.opacity * (-e).exp()).min(0.999);
                                if a >= ALPHA_MIN {
                                    wl.blended_pairs += 1;
                                    trans[g_mt][pi] = tcur * (1.0 - a);
                                }
                            }
                            if trans[g_mt][pi] >= 1e-4 {
                                all_sat = false;
                            }
                        }
                    }
                    // This mini-tile consumed one masked-in job.
                    tile.subtiles[sub_idx].sat[m] += 1;
                    if all_sat {
                        done[g_mt] = true;
                    }
                }
                tile.subtiles[sub_idx].jobs.push(GaussianJob { ctu_cycles, mask });
            }
        }
        wl.tiles.push(tile);
    }
    wl
}

/// The four 8×8 sub-tile rects of a 16×16 tile, row-major.
pub fn subtile_rects(tile: &Rect) -> [Rect; 4] {
    let mut out = [*tile; 4];
    for (i, r) in out.iter_mut().enumerate() {
        let sx = (i % 2) as f32;
        let sy = (i / 2) as f32;
        *r = Rect {
            x0: tile.x0 + sx * 8.0,
            y0: tile.y0 + sy * 8.0,
            x1: tile.x0 + sx * 8.0 + 8.0,
            y1: tile.y0 + sy * 8.0 + 8.0,
        };
    }
    out
}

/// Splat re-export for bench code.
pub type ProjectedSplat = Splat;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::camera::{Camera, Intrinsics};
    use crate::numeric::linalg::v3;
    use crate::scene::synthetic::{generate_scaled, preset};

    fn cam() -> Camera {
        Camera::look_at(
            Intrinsics::from_fov(128, 128, 1.2),
            v3(0.0, 2.5, -12.0),
            v3(0.0, 0.5, 0.0),
            v3(0.0, 1.0, 0.0),
        )
    }

    fn scene() -> Scene {
        generate_scaled(&preset("garden"), 0.01)
    }

    #[test]
    fn extract_from_plan_matches_extract() {
        // Reusing a render's FramePlan must produce the identical workload
        // trace (extract() is just build + extract_from_plan).
        let s = scene();
        let c = cam();
        let hw = HwConfig::flicker32();
        let base = extract(&s, &c, &hw);
        let plan = FramePlan::build(&s, &c, &RenderOptions::default());
        let reused = extract_from_plan(&s, &plan, &hw);
        assert_eq!(base.visible_splats, reused.visible_splats);
        assert_eq!(base.tile_pairs, reused.tile_pairs);
        assert_eq!(base.stage1_pairs, reused.stage1_pairs);
        assert_eq!(base.stage2_pairs, reused.stage2_pairs);
        assert_eq!(base.minitile_pairs, reused.minitile_pairs);
        assert_eq!(base.blended_pairs, reused.blended_pairs);
        assert_eq!(base.tiles.len(), reused.tiles.len());
    }

    #[test]
    fn extract_for_reuses_compatible_plans_and_falls_back() {
        let s = scene();
        let c = cam();
        let hw = HwConfig::flicker32();
        let base = extract(&s, &c, &hw);
        let opts = RenderOptions::default();
        let plan = FramePlan::build(&s, &c, &opts);
        let reused = extract_for(&s, &c, &opts, || &plan, &hw);
        assert_eq!(base.minitile_pairs, reused.minitile_pairs);
        assert_eq!(base.tile_pairs, reused.tile_pairs);
        // Incompatible geometry (OBB binning) must fall back to a fresh
        // default-geometry extraction WITHOUT touching the plan thunk.
        let obb_opts = RenderOptions {
            strategy: Strategy::Obb,
            ..RenderOptions::default()
        };
        let fell_back = extract_for(
            &s,
            &c,
            &obb_opts,
            || panic!("incompatible options must not build a plan"),
            &hw,
        );
        assert_eq!(base.minitile_pairs, fell_back.minitile_pairs);
        assert_eq!(base.tile_pairs, fell_back.tile_pairs);
    }

    #[test]
    fn gated_plan_extraction_cuts_subtile_traffic() {
        use crate::render::pyramid::GateConfig;
        let s = scene();
        let c = cam();
        let hw = HwConfig::flicker32();
        let plan_off = FramePlan::build(&s, &c, &RenderOptions::default());
        let off = extract_from_plan(&s, &plan_off, &hw);
        let plan_on = FramePlan::build(
            &s,
            &c,
            &RenderOptions {
                gate: GateConfig::on(),
                ..RenderOptions::default()
            },
        );
        let on = extract_from_plan(&s, &plan_on, &hw);
        // Same upstream visibility and binning.
        assert_eq!(off.visible_splats, on.visible_splats);
        assert_eq!(off.tile_pairs, on.tile_pairs);
        // Ungated: everything is submitted, gate counters stay zero.
        assert_eq!(off.splats_submitted, off.tile_pairs as u64);
        assert_eq!(off.gate_tile_rejected, 0);
        assert_eq!(off.gate_quad_rejected, 0);
        // Gated: every pair is either submitted or tile-rejected, the
        // sub-tile streams shrink, and (lossless threshold) blends don't.
        assert_eq!(on.splats_submitted + on.gate_tile_rejected, on.tile_pairs as u64);
        assert!(on.gate_tile_rejected > 0, "gate never fired");
        assert!(on.stage1_pairs < off.stage1_pairs);
        assert!(on.minitile_pairs <= off.minitile_pairs);
        assert_eq!(on.blended_pairs, off.blended_pairs, "default gate must be lossless");
    }

    #[test]
    fn ctu_prs_class_split_tracks_the_policy() {
        use crate::render::precision::PrecisionPolicy;
        let s = scene();
        let c = cam();
        let hw = HwConfig::flicker32();
        // Global precision: every PR lands in the configured tier's bucket.
        let plan = FramePlan::build(&s, &c, &RenderOptions::default());
        let global = extract_from_plan(&s, &plan, &hw);
        assert_eq!(global.ctu_prs_by_class.iter().sum::<u64>(), global.ctu_prs);
        assert_eq!(
            global.ctu_prs_by_class[class_index(hw.cat_precision)],
            global.ctu_prs
        );
        // Adaptive: the realized class mix splits the same total.
        let adaptive_plan = FramePlan::build(
            &s,
            &c,
            &RenderOptions {
                precision: PrecisionPolicy::adaptive(),
                ..RenderOptions::default()
            },
        );
        let adaptive = extract_from_plan(&s, &adaptive_plan, &hw);
        assert_eq!(adaptive.ctu_prs_by_class.iter().sum::<u64>(), adaptive.ctu_prs);
        assert_eq!(adaptive.ctu_prs, global.ctu_prs, "classing must not change PR counts");
        let populated = adaptive.ctu_prs_by_class.iter().filter(|&&x| x > 0).count();
        assert!(
            populated >= 2,
            "adaptive class mix degenerate: {:?}",
            adaptive.ctu_prs_by_class
        );
        // Rect: quadrant-weighted buckets still split the same total, and
        // per-quadrant refinement only moves PRs below the tile class, so
        // the fp32 bucket never grows past the adaptive run's.
        let rect_plan = FramePlan::build(
            &s,
            &c,
            &RenderOptions {
                precision: PrecisionPolicy::rect(),
                ..RenderOptions::default()
            },
        );
        let rect = extract_from_plan(&s, &rect_plan, &hw);
        assert_eq!(rect.ctu_prs_by_class.iter().sum::<u64>(), rect.ctu_prs);
        assert_eq!(rect.ctu_prs, global.ctu_prs, "rect classing must not change PR counts");
        let fp32 = class_index(crate::cat::Precision::Fp32);
        assert!(
            rect.ctu_prs_by_class[fp32] <= adaptive.ctu_prs_by_class[fp32],
            "rect fp32 bucket {} exceeds adaptive {}",
            rect.ctu_prs_by_class[fp32],
            adaptive.ctu_prs_by_class[fp32]
        );
    }

    #[test]
    fn absorb_aggregates_frames_into_one_trace() {
        let s = scene();
        let hw = HwConfig::flicker32();
        let c2 = Camera::look_at(
            Intrinsics::from_fov(128, 128, 1.2),
            v3(3.0, 2.5, -11.0),
            v3(0.0, 0.5, 0.0),
            v3(0.0, 1.0, 0.0),
        );
        let a = extract(&s, &cam(), &hw);
        let b = extract(&s, &c2, &hw);
        let mut agg = a.clone();
        agg.absorb(&b);
        assert_eq!(agg.tiles.len(), a.tiles.len() + b.tiles.len());
        assert_eq!(agg.tile_pairs, a.tile_pairs + b.tile_pairs);
        assert_eq!(agg.minitile_pairs, a.minitile_pairs + b.minitile_pairs);
        assert_eq!(agg.blended_pairs, a.blended_pairs + b.blended_pairs);
        assert_eq!(agg.ctu_prs, a.ctu_prs + b.ctu_prs);
        assert_eq!(
            agg.ctu_prs_by_class.iter().sum::<u64>(),
            a.ctu_prs + b.ctu_prs
        );
        // Shared scene store: the metadata universe does not double.
        assert_eq!(agg.scene_gaussians, a.scene_gaussians);
        // Stacked-frame pixel accounting keeps the per-pixel average exact.
        assert_eq!(agg.height, a.height + b.height);
        let expect = ((a.minitile_pairs + b.minitile_pairs) * 16) as f64
            / (128.0 * (a.height + b.height) as f64);
        assert!((agg.per_pixel_processed() - expect).abs() < 1e-12);
    }

    #[test]
    fn tile_count_matches_grid() {
        let wl = extract(&scene(), &cam(), &HwConfig::flicker32());
        assert_eq!(wl.tiles.len(), (128 / 16) * (128 / 16));
        assert_eq!(wl.width, 128);
    }

    #[test]
    fn ctu_reduces_minitile_pairs_vs_no_ctu() {
        let s = scene();
        let c = cam();
        let with = extract(&s, &c, &HwConfig::flicker32());
        let without = extract(&s, &c, &HwConfig::simplified32());
        assert!(
            with.minitile_pairs < without.minitile_pairs / 2,
            "CAT should cut mini-tile work sharply: {} vs {}",
            with.minitile_pairs,
            without.minitile_pairs
        );
        // Same visibility work upstream.
        assert_eq!(with.visible_splats, without.visible_splats);
        assert_eq!(with.stage1_pairs, without.stage1_pairs);
    }

    #[test]
    fn stage1_cuts_ctu_load() {
        let s = scene();
        let c = cam();
        let aabb = extract(&s, &c, &HwConfig::flicker32());
        let none = extract(
            &s,
            &c,
            &HwConfig {
                subtile_test: SubtileTest::None,
                ..HwConfig::flicker32()
            },
        );
        assert!(aabb.stage2_pairs < none.stage2_pairs);
        // Paper: ~30% CTU-load reduction from Stage 1. Accept a broad band.
        let cut = 1.0 - aabb.stage2_pairs as f64 / none.stage2_pairs as f64;
        assert!(cut > 0.10, "stage1 cut only {cut}");
    }

    #[test]
    fn obb_stage1_tighter_than_aabb() {
        let s = scene();
        let c = cam();
        let aabb = extract(&s, &c, &HwConfig::simplified32());
        let obb = extract(&s, &c, &HwConfig::gscore64());
        assert!(obb.stage2_pairs <= aabb.stage2_pairs);
    }

    #[test]
    fn sparse_mode_has_no_dense_jobs() {
        let wl = extract(&scene(), &cam(), &HwConfig::flicker32_sparse());
        assert_eq!(wl.dense_jobs, 0);
        assert!(wl.sparse_jobs > 0);
    }

    #[test]
    fn adaptive_mode_mixes() {
        let wl = extract(&scene(), &cam(), &HwConfig::flicker32());
        assert!(wl.dense_jobs > 0, "smooth gaussians exist");
        assert!(wl.sparse_jobs > 0, "spiky gaussians exist");
    }

    #[test]
    fn saturation_ordinals_bounded_by_masked_jobs() {
        let wl = extract(&scene(), &cam(), &HwConfig::flicker32());
        for tile in &wl.tiles {
            for st in &tile.subtiles {
                for m in 0..4usize {
                    let masked = st
                        .jobs
                        .iter()
                        .filter(|j| j.mask & (1 << m) != 0)
                        .count() as u32;
                    assert!(st.sat[m] <= masked, "sat {} > masked {}", st.sat[m], masked);
                }
            }
        }
    }

    #[test]
    fn per_pixel_processed_reasonable() {
        let wl = extract(&scene(), &cam(), &HwConfig::simplified32());
        let pp = wl.per_pixel_processed();
        assert!(pp > 1.0, "per-pixel {pp}");
        let wl2 = extract(&scene(), &cam(), &HwConfig::flicker32());
        assert!(wl2.per_pixel_processed() < pp * 0.5);
    }
}
