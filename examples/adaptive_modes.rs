//! Adaptive leader-pixel study (paper Sec. III-A / Fig. 3a): compare the
//! four sampling modes on every scene and show where Smooth-Focused vs
//! Spiky-Focused wins.
//!
//! Run: `cargo run --release --example adaptive_modes`

use flicker::cat::{CatConfig, CatEngine, LeaderMode, Precision};
use flicker::config::ExperimentConfig;
use flicker::coordinator::report::Report;
use flicker::coordinator::{Golden, Session};
use flicker::render::metrics::psnr;
use flicker::scene::synthetic::presets;

fn main() -> flicker::util::error::Result<()> {
    let mut report = Report::new(
        "adaptive_modes",
        "Leader-pixel modes across scenes (PSNR vs vanilla / leader-pixel saving)",
    );
    for preset in presets() {
        // One session per scene: the golden reference and all four
        // leader-pixel modes re-render the same cached FramePlan.
        let session = Session::builder(ExperimentConfig {
            scene: preset.name.into(),
            resolution: 160,
            frames: 1,
            ..Default::default()
        })
        .build()?;
        let golden = session.frame(0, &Golden)?;

        let mut metrics: Vec<(&str, f64)> = Vec::new();
        for (name, mode) in [
            ("dense", LeaderMode::UniformDense),
            ("sparse", LeaderMode::UniformSparse),
            ("smooth_f", LeaderMode::SmoothFocused),
            ("spiky_f", LeaderMode::SpikyFocused),
        ] {
            let mut engine = CatEngine::new(CatConfig {
                mode,
                precision: Precision::Fp32,
                stage1: true,
            });
            let out = session.plan(0).render_with(&mut engine, None);
            metrics.push((name, psnr(&golden.image, &out.image)));
        }
        assert_eq!(
            session.plan_cache_stats().builds,
            1,
            "golden + 4 modes must share one plan"
        );
        report.row(preset.name, &metrics);
    }
    report.emit();
    println!("Reading the table: 'dense' is the quality ceiling; the better");
    println!("adaptive mode per scene depends on whether detail lives in");
    println!("smooth or spiky Gaussians (paper Sec. III-A).");
    Ok(())
}
