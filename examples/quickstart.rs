//! Quickstart: drive the `coordinator::Session` API — render a synthetic
//! scene with and without Mini-Tile CAT from one cached `FramePlan`,
//! report the quality delta and the workload reduction, and run the cycle
//! simulator on both FLICKER and GSCore configurations.
//!
//! Run: `cargo run --release --example quickstart`

use flicker::cat::{CatConfig, CatEngine, LeaderMode, Precision};
use flicker::config::ExperimentConfig;
use flicker::coordinator::{Golden, GoldenCat, Session};
use flicker::render::metrics::{psnr, ssim};
use flicker::sim::top::simulate_frame;
use flicker::sim::HwConfig;
use flicker::util::pool::default_workers;

fn main() -> flicker::util::error::Result<()> {
    // One session = one prepared experiment: the scene, the cameras, the
    // resolved render options, and a lazily-built per-view FramePlan cache
    // every backend shares.
    let session = Session::builder(ExperimentConfig {
        scene: "garden".into(),
        resolution: 192,
        frames: 1,
        ..Default::default()
    })
    .build()?;
    let scene = session.scene();
    println!(
        "scene '{}': {} gaussians ({:.0}% spiky)",
        scene.name,
        scene.len(),
        scene.spiky_fraction(3.0) * 100.0
    );

    // 1) Vanilla render (golden model).
    let vanilla = session.frame(0, &Golden)?;
    println!(
        "vanilla:  {:.1} ms, {:.1} gaussians tested per pixel",
        vanilla.wall_ms,
        vanilla.stats.per_pixel_tested()
    );

    // 1b) Same frame with the tile fan-out on every core — bit-identical.
    // The builder's .scene() override reuses the already-built scene
    // instead of regenerating it.
    let par_session = Session::builder(ExperimentConfig {
        scene: "garden".into(),
        resolution: 192,
        frames: 1,
        workers: 0, // auto
        ..Default::default()
    })
    .scene(scene.clone())
    .build()?;
    let parallel = par_session.frame(0, &Golden)?;
    assert_eq!(
        vanilla.image.data, parallel.image.data,
        "tile-parallel render must match sequential bit-for-bit"
    );
    println!(
        "parallel: {:.1} ms on {} workers (bit-identical)",
        parallel.wall_ms,
        default_workers()
    );

    // 2) Mini-Tile CAT render (adaptive leaders, mixed precision) — the
    // same cached plan, a different backend: projection, tile binning, and
    // depth sorting do NOT run again.
    let cat_cfg = CatConfig {
        mode: LeaderMode::SmoothFocused,
        precision: Precision::Mixed,
        stage1: true,
    };
    let cat = session.frame(0, &GoldenCat(cat_cfg))?;
    println!(
        "with CAT: {:.1} ms, {:.1} gaussians tested per pixel",
        cat.wall_ms,
        cat.stats.per_pixel_tested()
    );
    println!(
        "quality:  {:.2} dB PSNR, {:.4} SSIM vs vanilla",
        psnr(&vanilla.image, &cat.image),
        ssim(&vanilla.image, &cat.image)
    );
    let cache = session.plan_cache_stats();
    println!(
        "plan cache: {} build, {} hits (vanilla + CAT shared one FramePlan)",
        cache.builds, cache.hits
    );

    // A standalone CAT engine exposes the Stage-1/Stage-2 filter funnel;
    // the session hands out its cached plan for stateful instrumentation.
    let mut engine = CatEngine::new(cat_cfg);
    let _ = session.plan(0).render_with(&mut engine, None);
    println!(
        "CAT funnel: stage1 cut {:.0}%, minitile pass rate {:.0}%, leader saving {:.0}%",
        engine.stats.stage1_reject_rate() * 100.0,
        engine.stats.minitile_pass_rate() * 100.0,
        engine.stats.leader_saving_vs_dense() * 100.0
    );

    // 3) Cycle-accurate simulation: FLICKER vs GSCore.
    for hw in [HwConfig::flicker32(), HwConfig::gscore64()] {
        let r = simulate_frame(scene, session.camera(0), &hw);
        println!(
            "sim {:<22} {:>9} render-cycles  {:>7.2} ms/frame  {:>6.1} µJ  (stall {:.1}%)",
            r.config,
            r.render_cycles,
            r.frame_ms,
            r.energy.total_uj(),
            r.pipe.stall_rate() * 100.0
        );
    }

    // 4) Save the CAT render.
    let out = std::path::Path::new("target/quickstart.ppm");
    std::fs::create_dir_all("target")?;
    cat.image.write_ppm(out)?;
    println!("wrote {}", out.display());
    Ok(())
}
