//! FIFO-depth tuning study (paper Sec. V-B / Fig. 9): sweep the feature-
//! FIFO depth, print the speedup/stall/SRAM trade-off, and report the knee.
//!
//! Run: `cargo run --release --example fifo_tuning [-- --scene garden]`

use flicker::config::ExperimentConfig;
use flicker::coordinator::Session;
use flicker::sim::area::{area, AreaParams};
use flicker::sim::top::simulate_workload;
use flicker::sim::workload::extract_for;
use flicker::sim::HwConfig;
use flicker::util::cli::Args;

fn main() -> flicker::util::error::Result<()> {
    let args = Args::from_env(&[]);
    let cfg = ExperimentConfig::from_args(&args)?;
    let session = Session::builder(cfg).build()?;
    let scene = session.scene();
    let cam = session.camera(0);
    let base = HwConfig {
        clustering: false,
        ..session.config().build_hw()?
    };
    // Reuse the session's cached FramePlan for the workload trace
    // (extract_for falls back to default geometry — and skips the plan
    // build entirely — when the configured geometry is incompatible).
    let wl = extract_for(scene, cam, session.options(), || session.plan(0), &base);

    let mut report = session.report("fifo_tuning", "FIFO depth: speedup / stalls / SRAM");
    let mut rows = Vec::new();
    for depth in [1usize, 2, 4, 8, 16, 32, 64, 128] {
        let hw = HwConfig {
            fifo_depth: depth,
            ..base.clone()
        };
        let r = simulate_workload(scene, cam, &hw, wl.clone());
        let fifo_mm2 = area(&hw, &AreaParams::default()).fifo_mm2;
        rows.push((depth, r.render_cycles, r.pipe.stall_rate(), fifo_mm2));
    }
    let d1 = rows[0].1 as f64;
    let max_speedup = rows.iter().map(|r| d1 / r.1 as f64).fold(0.0, f64::max);
    let mut knee = rows[0].0;
    for (depth, cycles, stall, mm2) in &rows {
        let speedup = d1 / *cycles as f64;
        if speedup >= 0.95 * max_speedup && knee == rows[0].0 && *depth != rows[0].0 {
            knee = *depth;
        }
        report.row(
            &format!("depth={depth}"),
            &[
                ("speedup", speedup),
                ("stall_rate", *stall),
                ("fifo_mm2", *mm2),
            ],
        );
    }
    report.emit();
    println!(
        "knee: depth {knee} reaches ≥95% of the max {max_speedup:.3}x — the paper picks 16 \
         (96% of max at 12.5% of depth-128's SRAM)."
    );
    Ok(())
}
