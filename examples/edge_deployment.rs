//! End-to-end edge deployment driver — the full-system validation run
//! recorded in EXPERIMENTS.md.
//!
//! Exercises every layer on a real workload through one
//! `coordinator::Session`: build + prune a scene (the paper's model
//! pipeline, with the `PruneReport` recorded as report provenance),
//! cluster it, render the camera orbit through BOTH the golden Rust
//! rasterizer and the AOT JAX/Pallas artifacts via PJRT from the same
//! cached per-view `FramePlan`s (proving L1/L2/L3 compose), verify the two
//! backends agree, and run the cycle-accurate simulator per frame for
//! FLICKER / GSCore / the edge GPU, reporting FPS, energy, and quality.
//!
//! Run: `cargo run --release --example edge_deployment`
//! (the PJRT leg needs a `--features pjrt` build with a real `xla` crate
//! plus `make artifacts`; it is skipped gracefully otherwise)

use flicker::config::ExperimentConfig;
use flicker::coordinator::{Golden, Session};
use flicker::scene::clustering::cluster;
use flicker::sim::gpu::{estimate, GpuParams};
use flicker::sim::top::simulate_frame;
use flicker::sim::workload::extract_for;
use flicker::sim::{HwConfig, SubtileTest};
use flicker::util::stats::harmonic_mean;

/// PJRT leg of the run: real when the feature + artifacts are available,
/// a no-op otherwise so the example always completes end-to-end.
#[cfg(feature = "pjrt")]
mod pjrt_leg {
    use flicker::coordinator::{Pjrt, Session};
    use flicker::render::image::Image;
    use flicker::render::metrics::{psnr, ssim};
    use flicker::runtime::{default_artifact_dir, Runtime};
    use flicker::util::error::Result;

    pub struct PjrtEval(Option<Runtime>);

    impl PjrtEval {
        pub fn init() -> PjrtEval {
            let dir = default_artifact_dir();
            if !dir.join("manifest.json").exists() {
                println!("NOTE: artifacts missing — run `make artifacts`; skipping PJRT backend");
                return PjrtEval(None);
            }
            match Runtime::load(&dir) {
                Ok(rt) => {
                    println!(
                        "pjrt: platform {}, {} artifacts",
                        rt.platform(),
                        rt.manifest.files.len()
                    );
                    PjrtEval(Some(rt))
                }
                Err(e) => {
                    println!("NOTE: pjrt runtime unavailable ({e}); skipping PJRT backend");
                    PjrtEval(None)
                }
            }
        }

        /// Render view `i` through PJRT from the session's cached plan,
        /// returning (wall_ms, psnr, ssim) vs golden.
        pub fn eval(
            &self,
            session: &Session,
            i: usize,
            golden: &Image,
        ) -> Result<Option<(f64, f64, f64)>> {
            let Some(rt) = &self.0 else { return Ok(None) };
            let m = session.frame(i, &Pjrt::new(rt))?;
            Ok(Some((m.wall_ms, psnr(golden, &m.image), ssim(golden, &m.image))))
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod pjrt_leg {
    use flicker::coordinator::Session;
    use flicker::render::image::Image;
    use flicker::util::error::Result;

    pub struct PjrtEval;

    impl PjrtEval {
        pub fn init() -> PjrtEval {
            println!("NOTE: built without `--features pjrt`; skipping PJRT backend");
            PjrtEval
        }

        pub fn eval(
            &self,
            _session: &Session,
            _i: usize,
            _golden: &Image,
        ) -> Result<Option<(f64, f64, f64)>> {
            Ok(None)
        }
    }
}

fn main() -> flicker::util::error::Result<()> {
    // ---- model pipeline: train-time preparation ----
    // `prune: true` runs contribution pruning during session build and
    // keeps the PruneReport for provenance.
    let session = Session::builder(ExperimentConfig {
        scene: "garden".into(),
        resolution: 192,
        frames: 4,
        prune: true,
        ..Default::default()
    })
    .build()?;
    let rep = session.prune_report().expect("prune requested").clone();
    let cl = cluster(session.scene(), 32);
    println!(
        "model prep: {} → {} gaussians (pruned), {} clusters (mean {:.1})",
        rep.before,
        rep.after,
        cl.num_clusters(),
        cl.mean_size()
    );

    // ---- PJRT runtime (L1/L2 artifacts) ----
    let pjrt = pjrt_leg::PjrtEval::init();

    let mut report = session.report(
        "edge_deployment",
        "End-to-end orbit on garden (pruned+clustered)",
    );
    let mut golden_ms = Vec::new();
    let mut pjrt_psnr = Vec::new();
    let mut fl_fps = Vec::new();
    let mut gs_fps = Vec::new();
    let mut xnx_fps = Vec::new();
    let mut fl_uj = Vec::new();

    for i in 0..session.num_frames() {
        let golden = session.frame(i, &Golden)?;
        golden_ms.push(golden.wall_ms);

        // PJRT backend: all three layers compose on one cached plan.
        let mut metrics: Vec<(&str, f64)> = vec![("golden_ms", golden.wall_ms)];
        if let Some((ms, p, s)) = pjrt.eval(&session, i, &golden.image)? {
            pjrt_psnr.push(p);
            metrics.push(("pjrt_ms", ms));
            metrics.push(("pjrt_psnr", p));
            metrics.push(("pjrt_ssim", s));
        }

        // Cycle-accurate accelerator + GPU baselines.
        let cam = session.camera(i);
        let fl = simulate_frame(session.scene(), cam, &HwConfig::flicker32());
        let gs = simulate_frame(session.scene(), cam, &HwConfig::gscore64());
        // The GPU-baseline workload reuses the plan session.frame already
        // built and cached for this exact view.
        let wl = extract_for(
            session.scene(),
            cam,
            session.options(),
            || session.plan(i),
            &HwConfig {
                subtile_test: SubtileTest::None,
                ..HwConfig::simplified32()
            },
        );
        let xnx = estimate(&wl, &GpuParams::xavier_nx());
        fl_fps.push(fl.fps);
        gs_fps.push(gs.fps);
        xnx_fps.push(xnx.fps);
        fl_uj.push(fl.energy.total_uj());
        metrics.push(("flicker_fps", fl.fps));
        metrics.push(("gscore_fps", gs.fps));
        metrics.push(("xnx_fps", xnx.fps));
        metrics.push(("flicker_uj", fl.energy.total_uj()));
        report.row(&format!("frame{i}"), &metrics);
    }
    report.emit();

    let fl = harmonic_mean(&fl_fps);
    let gs = harmonic_mean(&gs_fps);
    let xnx = harmonic_mean(&xnx_fps);
    println!("== summary ==");
    println!("golden render: {:.1} ms/frame host wall-clock", harmonic_mean(&golden_ms));
    if !pjrt_psnr.is_empty() {
        let worst = pjrt_psnr.iter().cloned().fold(f64::INFINITY, f64::min);
        println!("pjrt backend agrees with golden: worst PSNR {worst:.1} dB");
        assert!(worst > 25.0, "PJRT/golden divergence");
    }
    println!(
        "simulated FPS: flicker32 {fl:.1}, gscore64 {gs:.1}, edge GPU {xnx:.2} \
         (speedup vs GPU: {:.1}x / {:.1}x)",
        fl / xnx,
        gs / xnx
    );
    println!(
        "flicker energy: {:.1} µJ/frame avg",
        fl_uj.iter().sum::<f64>() / fl_uj.len() as f64
    );
    assert!(fl > xnx, "accelerator must beat the edge GPU");
    println!("edge_deployment OK");
    Ok(())
}
