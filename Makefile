# FLICKER build entry points. `make ci` mirrors .github/workflows/ci.yml so
# the tier-1 command (`cargo build --release && cargo test -q`) and CI never
# drift.

CARGO ?= cargo
PYTHON ?= python3

.PHONY: build test bench bench-smoke bench-record prop-heavy examples fmt clippy docs artifacts pytest ci clean

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

# Build the benches (paper figures/tables) under the Cargo layout.
bench:
	$(CARGO) bench --no-run

# Run every bench once at tiny scale (`--quick` halves the resolution and
# drops to 1 warmup + 3 samples) so bench targets can't bitrot between
# perf PRs. Mirrored by the CI bench-smoke lane. The second invocation
# re-runs hotpath with the pjrt feature so the exec_tile_single /
# exec_tile_batched rows (stub-backed) can't bitrot either.
bench-smoke:
	$(CARGO) bench -- --quick
	$(CARGO) bench --features pjrt --bench hotpath -- --quick

# Record the perf trajectory (CI: bench-record lane, push-to-main only):
# run hotpath (with the pjrt feature so the exec_tile_single/batched rows
# land, stub-backed), the gating bench, the temporal plan-delta bench, the
# adaptive-precision bench, and the multi-tenant service bench (with the
# pjrt feature so the coalesced fill-rate rows land, stub-backed) in quick
# mode, then merge their JSON sidecars into a commit-stamped BENCH_10.json.
bench-record:
	$(CARGO) bench --features pjrt --bench hotpath -- --quick
	$(CARGO) bench --bench fig11_gating -- --quick
	$(CARGO) bench --bench fig12_temporal -- --quick
	$(CARGO) bench --bench fig13_precision -- --quick
	$(CARGO) bench --features pjrt --bench fig14_service -- --quick
	$(PYTHON) scripts/collect_bench.py BENCH_10.json

# Heavier property coverage (CI: prop-heavy lane): 512 generated cases per
# property across the property suite (including the temporal plan-delta
# chain/motion-bound properties), the plan-delta differential harness, and
# the PJRT roundtrip tests, running against the offline stub runtime.
prop-heavy:
	FLICKER_PROP_CASES=512 $(CARGO) test -q --features pjrt --test properties --test plan_delta --test pjrt_roundtrip

# Run the Session-API showcase examples end-to-end (CI: examples lane) so
# the quickstart code in README/examples can't bitrot.
examples:
	$(CARGO) run --release --example quickstart
	$(CARGO) run --release --example adaptive_modes

fmt:
	$(CARGO) fmt --all -- --check

# --all-features keeps the pjrt-gated code (executor waves, stub kernels)
# under the same lint bar as the default build.
clippy:
	$(CARGO) clippy --all-targets --all-features -- -D warnings

# API docs must build warning-free (missing_docs is warn at the crate
# root), and the doctest examples must pass.
docs:
	RUSTDOCFLAGS="-D warnings" $(CARGO) doc --no-deps
	$(CARGO) test --doc

# AOT-lower the JAX/Pallas kernels to HLO text for the Rust PJRT runtime.
# Writes rust/artifacts/ (the location `default_artifact_dir` resolves from
# both the CLI and `cargo test`). Requires jax.
artifacts:
	cd python && $(PYTHON) -m compile.aot --out-dir ../rust/artifacts

# Python kernel tests; skips cleanly when pytest (or jax) is unavailable.
pytest:
	@if $(PYTHON) -c "import pytest" 2>/dev/null; then \
		$(PYTHON) -m pytest python/tests -q; \
	else \
		echo "pytest not installed - skipping python tests"; \
	fi

ci: build test fmt clippy docs pytest bench-smoke examples
	$(CARGO) build --release --features pjrt
	$(CARGO) test -q --features pjrt
	$(MAKE) prop-heavy

clean:
	$(CARGO) clean
