"""Pure-jnp reference oracles for the Pallas kernels.

These are the correctness contracts: every Pallas kernel in this package is
asserted allclose against the function of the same name here (pytest +
hypothesis sweeps in python/tests/). They also define the L2 math that the
Rust golden model mirrors (rust/src/cat/pr.rs, rust/src/render/*).
"""

import jax.numpy as jnp

# Minimum contributing alpha (1/255) - paper Eq. 1 threshold.
ALPHA_MIN = 1.0 / 255.0


def pr_weights_ref(mu, conic, p_top, p_bot):
    """Pixel-Rectangle Gaussian weights (paper Alg. 1), batched.

    Args:
      mu:    (N, 2) projected means.
      conic: (N, 3) inverse-covariance entries (a, b, c).
      p_top: (M, 2) main-diagonal top pixel per PR.
      p_bot: (M, 2) main-diagonal bottom pixel per PR.

    Returns:
      (M, N, 4) weights E at corners [(xt,yt), (xb,yt), (xt,yb), (xb,yb)].
    """
    dtx = p_top[:, None, 0] - mu[None, :, 0]  # (M, N)
    dty = p_top[:, None, 1] - mu[None, :, 1]
    dbx = p_bot[:, None, 0] - mu[None, :, 0]
    dby = p_bot[:, None, 1] - mu[None, :, 1]
    ca = conic[None, :, 0]
    cb = conic[None, :, 1]
    cc = conic[None, :, 2]
    s_tx = 0.5 * dtx * dtx * ca
    s_ty = 0.5 * dty * dty * cc
    s_bx = 0.5 * dbx * dbx * ca
    s_by = 0.5 * dby * dby * cc
    t0 = dtx * dty * cb
    t1 = dbx * dty * cb
    t2 = dtx * dby * cb
    t3 = dbx * dby * cb
    e0 = s_tx + s_ty + t0
    e1 = s_bx + s_ty + t1
    e2 = s_tx + s_by + t2
    e3 = s_bx + s_by + t3
    return jnp.stack([e0, e1, e2, e3], axis=-1)


def cat_masks_ref(mu, conic, opacity, p_top, p_bot):
    """Eq. 2 decisions for a batch of PRs: ln(255*o) > E.

    Returns (M, N, 4) boolean pass masks.
    """
    e = pr_weights_ref(mu, conic, p_top, p_bot)
    lhs = jnp.log(255.0 * jnp.maximum(opacity, 1e-12))  # (N,)
    return lhs[None, :, None] > e


def alpha_map_ref(mu, conic, opacity, origin, tile=16):
    """Per-pixel alpha (Eq. 1) of N splats over a tile x tile pixel block.

    Returns (N, tile, tile) alphas clamped to <= 0.999 (3DGS convention).
    """
    xs = origin[0] + jnp.arange(tile, dtype=jnp.float32) + 0.5
    ys = origin[1] + jnp.arange(tile, dtype=jnp.float32) + 0.5
    dx = xs[None, None, :] - mu[:, 0, None, None]  # (N, 1, T)
    dy = ys[None, :, None] - mu[:, 1, None, None]  # (N, T, 1)
    ca = conic[:, 0, None, None]
    cb = conic[:, 1, None, None]
    cc = conic[:, 2, None, None]
    e = 0.5 * (ca * dx * dx + cc * dy * dy) + cb * dx * dy
    alpha = opacity[:, None, None] * jnp.exp(-e)
    return jnp.minimum(alpha, 0.999)


def blend_tile_ref(mu, conic, opacity, color, origin, t_min=1e-4, tile=16):
    """Front-to-back alpha blending of depth-sorted splats over one tile.

    Args:
      mu/conic/opacity: (N, .) splat features, already depth-sorted.
      color: (N, 3) view-evaluated RGB.
      origin: (2,) tile pixel origin.

    Returns (tile, tile, 3) color and (tile, tile) final transmittance.
    """
    alphas = alpha_map_ref(mu, conic, opacity, origin, tile)  # (N, T, T)
    # Alpha below 1/255 contributes nothing (paper's skip threshold).
    alphas = jnp.where(alphas >= ALPHA_MIN, alphas, 0.0)

    # Transmittance before splat i: T_i = prod_{j<i} (1 - alpha_j), with the
    # 3DGS stop rule: once T < t_min the pixel stops accumulating.
    one_minus = 1.0 - alphas
    t_after = jnp.cumprod(one_minus, axis=0)  # (N, T, T): T after splat i
    t_before = jnp.concatenate(
        [jnp.ones_like(alphas[:1]), t_after[:-1]], axis=0
    )
    active = t_before >= t_min
    w = jnp.where(active, alphas * t_before, 0.0)  # (N, T, T)
    rgb = jnp.einsum("nij,nc->ijc", w, color)
    # Early termination freezes T at its first value below t_min (the pixel
    # stops blending). Since t_after is non-increasing, that first value is
    # the largest of those below the threshold.
    crossed = t_after < t_min
    frozen = jnp.where(crossed, t_after, -jnp.inf).max(axis=0)
    t_final = jnp.where(crossed.any(axis=0), frozen, t_after[-1])
    return rgb, t_final


def project_ref(pos_cam, fx, fy, cx, cy, cov3_cam, dilation=0.3):
    """EWA projection of camera-space Gaussians to 2D splats.

    Args:
      pos_cam: (N, 3) Gaussian centers in camera space (z > 0 assumed;
               frustum culling happens upstream in the coordinator).
      cov3_cam: (N, 3, 3) 3D covariance already rotated into camera space.

    Returns dict with mean (N,2), cov (N,3) [a,b,c], conic (N,3), depth (N,),
    radius (N,).
    """
    x, y, z = pos_cam[:, 0], pos_cam[:, 1], pos_cam[:, 2]
    inv_z = 1.0 / z
    mean = jnp.stack([fx * x * inv_z + cx, fy * y * inv_z + cy], axis=-1)

    # Jacobian rows: [fx/z, 0, -fx*x/z^2], [0, fy/z, -fy*y/z^2].
    j00 = fx * inv_z
    j02 = -fx * x * inv_z * inv_z
    j11 = fy * inv_z
    j12 = -fy * y * inv_z * inv_z

    c = cov3_cam
    # Sigma2D = J Sigma J^T for the 2x3 Jacobian (rows [j00,0,j02],[0,j11,j12]).
    a = (
        j00 * j00 * c[:, 0, 0]
        + 2.0 * j00 * j02 * c[:, 0, 2]
        + j02 * j02 * c[:, 2, 2]
    ) + dilation
    b = (
        j00 * j11 * c[:, 0, 1]
        + j00 * j12 * c[:, 0, 2]
        + j02 * j11 * c[:, 2, 1]
        + j02 * j12 * c[:, 2, 2]
    )
    cc = (
        j11 * j11 * c[:, 1, 1]
        + 2.0 * j11 * j12 * c[:, 1, 2]
        + j12 * j12 * c[:, 2, 2]
    ) + dilation

    det = a * cc - b * b
    inv_det = 1.0 / det
    conic = jnp.stack([cc * inv_det, -b * inv_det, a * inv_det], axis=-1)

    mid = 0.5 * (a + cc)
    lam1 = mid + jnp.sqrt(jnp.maximum(mid * mid - det, 0.0))
    radius = 3.0 * jnp.sqrt(lam1)

    return {
        "mean": mean,
        "cov": jnp.stack([a, b, cc], axis=-1),
        "conic": conic,
        "depth": z,
        "radius": radius,
    }


def quantize_fp16(x):
    """Round-trip through IEEE half (the FP16 stage of the mixed path)."""
    return x.astype(jnp.float16).astype(jnp.float32)


def quantize_fp8(x):
    """Round-trip through FP8 E4M3, saturating at the format max (448).

    Hardware convert units saturate; XLA's cast overflows to NaN (E4M3 has
    no infinity), so clamp first. Matches rust/src/numeric/fp8.rs.
    """
    return jnp.clip(x, -448.0, 448.0).astype(jnp.float8_e4m3fn).astype(jnp.float32)


def _identity(x):
    return x


# Per-precision rounding plan (delta, conic, multiply, accumulate) —
# mirrors rust/src/cat/mixed.rs `pr_weights_quant` scheme for scheme.
_QUANT_SCHEMES = {
    "fp32": (lambda p, m: p - m, _identity, _identity, _identity),
    "fp16": (
        lambda p, m: quantize_fp16(quantize_fp16(p) - quantize_fp16(m)),
        quantize_fp16,
        quantize_fp16,
        quantize_fp16,
    ),
    "fp8": (
        lambda p, m: quantize_fp8(quantize_fp8(p) - quantize_fp8(m)),
        quantize_fp8,
        quantize_fp8,
        quantize_fp8,
    ),
    "mixed": (
        lambda p, m: quantize_fp8(quantize_fp16(quantize_fp16(p) - quantize_fp16(m))),
        quantize_fp8,
        quantize_fp8,
        quantize_fp16,
    ),
}


def pr_weights_quant_ref(mu, conic, p_top, p_bot, precision):
    """Alg. 1 under a precision scheme (paper Sec. IV-C): quantize at the
    exact points the CTU hardware converts. ``fp16`` runs everything at
    FP16, ``fp8`` everything at E4M3 including the absolute coordinates,
    and ``mixed`` keeps line 1 at FP16 before narrowing to FP8 products
    with FP16 accumulation (QAU)."""
    delta, qc, qm, qa = _QUANT_SCHEMES[precision]
    dtx = delta(p_top[:, None, 0], mu[None, :, 0])
    dty = delta(p_top[:, None, 1], mu[None, :, 1])
    dbx = delta(p_bot[:, None, 0], mu[None, :, 0])
    dby = delta(p_bot[:, None, 1], mu[None, :, 1])
    ca = qc(conic[None, :, 0])
    cb = qc(conic[None, :, 1])
    cc = qc(conic[None, :, 2])
    s_tx = qm(qm(0.5 * dtx * dtx) * ca)
    s_ty = qm(qm(0.5 * dty * dty) * cc)
    s_bx = qm(qm(0.5 * dbx * dbx) * ca)
    s_by = qm(qm(0.5 * dby * dby) * cc)
    t0 = qm(qm(dtx * dty) * cb)
    t1 = qm(qm(dbx * dty) * cb)
    t2 = qm(qm(dtx * dby) * cb)
    t3 = qm(qm(dbx * dby) * cb)
    e0 = qa(qa(s_tx + s_ty) + t0)
    e1 = qa(qa(s_bx + s_ty) + t1)
    e2 = qa(qa(s_tx + s_by) + t2)
    e3 = qa(qa(s_bx + s_by) + t3)
    return jnp.stack([e0, e1, e2, e3], axis=-1)


def pr_weights_mixed_ref(mu, conic, p_top, p_bot):
    """Mixed-precision Alg. 1 (paper Sec. IV-C): deltas in FP16, converted
    to FP8 for the quadratic stage, FP16 accumulation (QAU)."""
    return pr_weights_quant_ref(mu, conic, p_top, p_bot, "mixed")
