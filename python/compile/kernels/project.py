"""Layer-1 Pallas kernel: EWA projection (3D camera-space -> 2D splats).

Elementwise over Gaussians: quaternion -> rotation, Sigma = R S S^T R^T in
camera space is prepared by the caller (model.py fuses the world->camera
rotation); this kernel applies the perspective Jacobian, covariance
dilation, conic inversion, and 3-sigma radius - the preprocessing core's
datapath (paper Fig. 5).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 128
DILATION = 0.3


def _project_kernel(pos_ref, cov_ref, cam_ref, mean_ref, conic_ref,
                    depth_ref, radius_ref):
    pos = pos_ref[...]            # (B, 3)
    cov = cov_ref[...]            # (B, 6) packed symmetric [xx,xy,xz,yy,yz,zz]
    fx = cam_ref[0]
    fy = cam_ref[1]
    cx = cam_ref[2]
    cy = cam_ref[3]

    x, y, z = pos[:, 0], pos[:, 1], pos[:, 2]
    inv_z = 1.0 / z
    mean_ref[...] = jnp.stack([fx * x * inv_z + cx, fy * y * inv_z + cy], axis=-1)
    depth_ref[...] = z

    j00 = fx * inv_z
    j02 = -fx * x * inv_z * inv_z
    j11 = fy * inv_z
    j12 = -fy * y * inv_z * inv_z

    cxx, cxy, cxz = cov[:, 0], cov[:, 1], cov[:, 2]
    cyy, cyz, czz = cov[:, 3], cov[:, 4], cov[:, 5]

    a = j00 * j00 * cxx + 2.0 * j00 * j02 * cxz + j02 * j02 * czz + DILATION
    b = (j00 * j11 * cxy + j00 * j12 * cxz + j02 * j11 * cyz + j02 * j12 * czz)
    c = j11 * j11 * cyy + 2.0 * j11 * j12 * cyz + j12 * j12 * czz + DILATION

    det = a * c - b * b
    inv_det = 1.0 / det
    conic_ref[...] = jnp.stack([c * inv_det, -b * inv_det, a * inv_det], axis=-1)

    mid = 0.5 * (a + c)
    lam1 = mid + jnp.sqrt(jnp.maximum(mid * mid - det, 0.0))
    radius_ref[...] = 3.0 * jnp.sqrt(lam1)


@jax.jit
def project(pos_cam, cov6_cam, cam_params):
    """Project camera-space Gaussians.

    Shapes: pos_cam (N,3), cov6_cam (N,6) packed [xx,xy,xz,yy,yz,zz],
    cam_params (4,) = [fx, fy, cx, cy]. N must be a multiple of BLOCK.
    Returns (mean (N,2), conic (N,3), depth (N,), radius (N,)).
    """
    n = pos_cam.shape[0]
    assert n % BLOCK == 0, f"N={n} not a multiple of {BLOCK}"
    grid = (n // BLOCK,)
    return pl.pallas_call(
        _project_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK, 3), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK, 6), lambda i: (i, 0)),
            pl.BlockSpec((4,), lambda i: (0,)),
        ],
        out_specs=(
            pl.BlockSpec((BLOCK, 2), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK, 3), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
            pl.BlockSpec((BLOCK,), lambda i: (i,)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((n, 2), jnp.float32),
            jax.ShapeDtypeStruct((n, 3), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.float32),
        ),
        interpret=True,
    )(pos_cam, cov6_cam, cam_params)
