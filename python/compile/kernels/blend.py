"""Layer-1 Pallas kernel: front-to-back alpha blending of one 16x16 tile.

TPU adaptation of the VRU array (DESIGN.md section Hardware-Adaptation): a
rendering core's 32 pixel lanes become a (16,16) VMEM-resident register
tile; the depth-ordered Gaussian list is walked with a fori_loop carrying
the (color, transmittance) state, which XLA keeps in registers/VMEM. The
ASIC's per-mini-tile early termination becomes mask-predicated updates: a
saturated pixel (T < t_min) simply stops changing, matching the functional
semantics of the hardware skip (the *scheduling* skip is modeled by the
Rust cycle simulator, which decides what enters this kernel).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ALPHA_MIN = 1.0 / 255.0
TILE = 16


def _blend_kernel(mu_ref, conic_ref, opacity_ref, color_ref, origin_ref,
                  rgb_ref, trans_ref, *, t_min):
    n = mu_ref.shape[0]
    ox = origin_ref[0]
    oy = origin_ref[1]
    xs = ox + jnp.arange(TILE, dtype=jnp.float32) + 0.5   # (T,)
    ys = oy + jnp.arange(TILE, dtype=jnp.float32) + 0.5

    def body(i, state):
        rgb, trans = state  # (T,T,3), (T,T)
        dx = xs[None, :] - mu_ref[i, 0]      # (1,T) broadcast over rows
        dy = ys[:, None] - mu_ref[i, 1]      # (T,1)
        e = (0.5 * (conic_ref[i, 0] * dx * dx + conic_ref[i, 2] * dy * dy)
             + conic_ref[i, 1] * dx * dy)
        alpha = jnp.minimum(opacity_ref[i] * jnp.exp(-e), 0.999)
        alpha = jnp.where(alpha >= ALPHA_MIN, alpha, 0.0)
        active = trans >= t_min
        w = jnp.where(active, alpha * trans, 0.0)
        rgb = rgb + w[:, :, None] * color_ref[i]
        trans = jnp.where(active, trans * (1.0 - alpha), trans)
        return rgb, trans

    rgb0 = jnp.zeros((TILE, TILE, 3), jnp.float32)
    t0 = jnp.ones((TILE, TILE), jnp.float32)
    rgb, trans = jax.lax.fori_loop(0, n, body, (rgb0, t0))
    rgb_ref[...] = rgb
    trans_ref[...] = trans


@functools.partial(jax.jit, static_argnames=("t_min",))
def blend_tile(mu, conic, opacity, color, origin, t_min=1e-4):
    """Blend N depth-sorted splats over one tile.

    Shapes: mu (N,2), conic (N,3), opacity (N,), color (N,3), origin (2,).
    Returns rgb (16,16,3) and transmittance (16,16). Padding convention:
    splats with opacity 0 are no-ops, so callers pad N freely.
    """
    kernel = functools.partial(_blend_kernel, t_min=t_min)
    return pl.pallas_call(
        kernel,
        out_shape=(
            jax.ShapeDtypeStruct((TILE, TILE, 3), jnp.float32),
            jax.ShapeDtypeStruct((TILE, TILE), jnp.float32),
        ),
        interpret=True,
    )(mu, conic, opacity, color, origin)
