"""Layer-1 Pallas kernel: Pixel-Rectangle Gaussian weights (paper Alg. 1).

TPU adaptation of the PRTU (DESIGN.md section Hardware-Adaptation): instead of
two PRTUs sharing registers, the kernel tiles the (PR, Gaussian) grid into
VMEM blocks and exploits the same corner symmetry in vectorized form - the
per-axis terms s_x, s_y are computed once per (PR, Gaussian) pair and the
four corners are assembled by cheap adds, mirroring the ~2x multiply saving
of the hardware unit.

The mixed-precision variant emulates the CTU datapath with
quantize-dequantize pairs (fp16 deltas -> fp8 products -> fp16 accumulate);
on a real TPU these map onto bf16 MXU passes.

All kernels run with interpret=True: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and correctness (not CPU wallclock) is the goal of the
interpret path.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# VMEM block sizes: 8 PRs x 128 Gaussians x 4 corners of f32 = 16 KiB per
# operand block - comfortably inside a TPU core's ~16 MiB VMEM with double
# buffering.
BLOCK_M = 8
BLOCK_N = 128


def _q16(x):
    return x.astype(jnp.float16).astype(jnp.float32)


def _q8(x):
    # Saturate at the E4M3 max like a hardware convert unit (XLA's raw cast
    # overflows to NaN instead).
    return jnp.clip(x, -448.0, 448.0).astype(jnp.float8_e4m3fn).astype(jnp.float32)


def _pr_weight_kernel(mu_ref, conic_ref, ptop_ref, pbot_ref, out_ref, *, mixed):
    """One (BLOCK_M, BLOCK_N) grid step."""
    mu = mu_ref[...]          # (BLOCK_N, 2)
    conic = conic_ref[...]    # (BLOCK_N, 3)
    ptop = ptop_ref[...]      # (BLOCK_M, 2)
    pbot = pbot_ref[...]      # (BLOCK_M, 2)

    if mixed:
        # Line 1 at FP16, then convert to FP8 (the paper's key trick:
        # subtract *before* narrowing, so relative position survives).
        dtx = _q8(_q16(_q16(ptop[:, None, 0]) - _q16(mu[None, :, 0])))
        dty = _q8(_q16(_q16(ptop[:, None, 1]) - _q16(mu[None, :, 1])))
        dbx = _q8(_q16(_q16(pbot[:, None, 0]) - _q16(mu[None, :, 0])))
        dby = _q8(_q16(_q16(pbot[:, None, 1]) - _q16(mu[None, :, 1])))
        ca = _q8(conic[None, :, 0])
        cb = _q8(conic[None, :, 1])
        cc = _q8(conic[None, :, 2])
        qm, qa = _q8, _q16
    else:
        dtx = ptop[:, None, 0] - mu[None, :, 0]
        dty = ptop[:, None, 1] - mu[None, :, 1]
        dbx = pbot[:, None, 0] - mu[None, :, 0]
        dby = pbot[:, None, 1] - mu[None, :, 1]
        ca = conic[None, :, 0]
        cb = conic[None, :, 1]
        cc = conic[None, :, 2]
        qm = qa = lambda x: x

    # Lines 2-3: per-axis quadratic terms (shared between corners).
    s_tx = qm(qm(0.5 * dtx * dtx) * ca)
    s_ty = qm(qm(0.5 * dty * dty) * cc)
    s_bx = qm(qm(0.5 * dbx * dbx) * ca)
    s_by = qm(qm(0.5 * dby * dby) * cc)
    # Lines 4-5: cross terms.
    t0 = qm(qm(dtx * dty) * cb)
    t1 = qm(qm(dbx * dty) * cb)
    t2 = qm(qm(dtx * dby) * cb)
    t3 = qm(qm(dbx * dby) * cb)
    # Lines 6-7: corner assembly (QAU accumulate precision).
    e0 = qa(qa(s_tx + s_ty) + t0)
    e1 = qa(qa(s_bx + s_ty) + t1)
    e2 = qa(qa(s_tx + s_by) + t2)
    e3 = qa(qa(s_bx + s_by) + t3)
    out_ref[...] = jnp.stack([e0, e1, e2, e3], axis=-1)


@functools.partial(jax.jit, static_argnames=("mixed",))
def pr_weights(mu, conic, p_top, p_bot, mixed=False):
    """Batched Alg. 1 on the Pallas grid.

    Shapes: mu (N,2), conic (N,3), p_top/p_bot (M,2) -> (M,N,4).
    M must be a multiple of BLOCK_M and N of BLOCK_N (the coordinator pads).
    """
    m, n = p_top.shape[0], mu.shape[0]
    assert m % BLOCK_M == 0, f"M={m} not a multiple of {BLOCK_M}"
    assert n % BLOCK_N == 0, f"N={n} not a multiple of {BLOCK_N}"
    grid = (m // BLOCK_M, n // BLOCK_N)
    kernel = functools.partial(_pr_weight_kernel, mixed=mixed)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_N, 2), lambda i, j: (j, 0)),
            pl.BlockSpec((BLOCK_N, 3), lambda i, j: (j, 0)),
            pl.BlockSpec((BLOCK_M, 2), lambda i, j: (i, 0)),
            pl.BlockSpec((BLOCK_M, 2), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_M, BLOCK_N, 4), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n, 4), jnp.float32),
        interpret=True,
    )(mu, conic, p_top, p_bot)


@jax.jit
def cat_masks(mu, conic, opacity, p_top, p_bot):
    """Eq. 2 pass masks from the Pallas weights: ln(255*o) > E.

    Returns (M, N, 4) float32 in {0,1} (bool upsets some PJRT paths).
    """
    e = pr_weights(mu, conic, p_top, p_bot, mixed=False)
    lhs = jnp.log(255.0 * jnp.maximum(opacity, 1e-12))
    return (lhs[None, :, None] > e).astype(jnp.float32)
