"""Layer-1 Pallas kernel: Pixel-Rectangle Gaussian weights (paper Alg. 1).

TPU adaptation of the PRTU (DESIGN.md section Hardware-Adaptation): instead of
two PRTUs sharing registers, the kernel tiles the (PR, Gaussian) grid into
VMEM blocks and exploits the same corner symmetry in vectorized form - the
per-axis terms s_x, s_y are computed once per (PR, Gaussian) pair and the
four corners are assembled by cheap adds, mirroring the ~2x multiply saving
of the hardware unit.

The precision variants emulate the CTU datapath with quantize-dequantize
pairs at the exact points the hardware converts (rust/src/cat/mixed.rs is
the authoritative scheme table): ``fp16`` runs everything at FP16, ``fp8``
everything at E4M3 including the absolute coordinates, and ``mixed`` keeps
line 1 (the subtract) at FP16 before narrowing to FP8 products with FP16
accumulation. On a real TPU these map onto bf16 MXU passes.

All kernels run with interpret=True: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and correctness (not CPU wallclock) is the goal of the
interpret path.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# VMEM block sizes: 8 PRs x 128 Gaussians x 4 corners of f32 = 16 KiB per
# operand block - comfortably inside a TPU core's ~16 MiB VMEM with double
# buffering.
BLOCK_M = 8
BLOCK_N = 128


def _q16(x):
    return x.astype(jnp.float16).astype(jnp.float32)


def _q8(x):
    # Saturate at the E4M3 max like a hardware convert unit (XLA's raw cast
    # overflows to NaN instead).
    return jnp.clip(x, -448.0, 448.0).astype(jnp.float8_e4m3fn).astype(jnp.float32)


def _id(x):
    return x


# Per-precision rounding plan: (delta, conic, multiply, accumulate).
# ``delta(p, m)`` is Alg. 1 line 1; the rest follow rust/src/cat/mixed.rs.
_SCHEMES = {
    "fp32": (lambda p, m: p - m, _id, _id, _id),
    # All operands + ops at FP16.
    "fp16": (lambda p, m: _q16(_q16(p) - _q16(m)), _q16, _q16, _q16),
    # Everything at E4M3 — including the absolute coordinates.
    "fp8": (lambda p, m: _q8(_q8(p) - _q8(m)), _q8, _q8, _q8),
    # Line 1 at FP16, then convert to FP8 (the paper's key trick:
    # subtract *before* narrowing, so relative position survives);
    # FP8 products, FP16 accumulation (QAU).
    "mixed": (lambda p, m: _q8(_q16(_q16(p) - _q16(m))), _q8, _q8, _q16),
}

PRECISIONS = tuple(_SCHEMES)


def _pr_weight_kernel(mu_ref, conic_ref, ptop_ref, pbot_ref, out_ref, *, precision):
    """One (BLOCK_M, BLOCK_N) grid step."""
    mu = mu_ref[...]          # (BLOCK_N, 2)
    conic = conic_ref[...]    # (BLOCK_N, 3)
    ptop = ptop_ref[...]      # (BLOCK_M, 2)
    pbot = pbot_ref[...]      # (BLOCK_M, 2)

    delta, qc, qm, qa = _SCHEMES[precision]
    dtx = delta(ptop[:, None, 0], mu[None, :, 0])
    dty = delta(ptop[:, None, 1], mu[None, :, 1])
    dbx = delta(pbot[:, None, 0], mu[None, :, 0])
    dby = delta(pbot[:, None, 1], mu[None, :, 1])
    ca = qc(conic[None, :, 0])
    cb = qc(conic[None, :, 1])
    cc = qc(conic[None, :, 2])

    # Lines 2-3: per-axis quadratic terms (shared between corners).
    s_tx = qm(qm(0.5 * dtx * dtx) * ca)
    s_ty = qm(qm(0.5 * dty * dty) * cc)
    s_bx = qm(qm(0.5 * dbx * dbx) * ca)
    s_by = qm(qm(0.5 * dby * dby) * cc)
    # Lines 4-5: cross terms.
    t0 = qm(qm(dtx * dty) * cb)
    t1 = qm(qm(dbx * dty) * cb)
    t2 = qm(qm(dtx * dby) * cb)
    t3 = qm(qm(dbx * dby) * cb)
    # Lines 6-7: corner assembly (QAU accumulate precision).
    e0 = qa(qa(s_tx + s_ty) + t0)
    e1 = qa(qa(s_bx + s_ty) + t1)
    e2 = qa(qa(s_tx + s_by) + t2)
    e3 = qa(qa(s_bx + s_by) + t3)
    out_ref[...] = jnp.stack([e0, e1, e2, e3], axis=-1)


@functools.partial(jax.jit, static_argnames=("precision",))
def pr_weights(mu, conic, p_top, p_bot, precision="fp32"):
    """Batched Alg. 1 on the Pallas grid.

    Shapes: mu (N,2), conic (N,3), p_top/p_bot (M,2) -> (M,N,4).
    M must be a multiple of BLOCK_M and N of BLOCK_N (the coordinator pads).
    ``precision`` is one of ``PRECISIONS`` ("fp32"|"fp16"|"fp8"|"mixed").
    """
    assert precision in _SCHEMES, f"unknown precision {precision!r}"
    m, n = p_top.shape[0], mu.shape[0]
    assert m % BLOCK_M == 0, f"M={m} not a multiple of {BLOCK_M}"
    assert n % BLOCK_N == 0, f"N={n} not a multiple of {BLOCK_N}"
    grid = (m // BLOCK_M, n // BLOCK_N)
    kernel = functools.partial(_pr_weight_kernel, precision=precision)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_N, 2), lambda i, j: (j, 0)),
            pl.BlockSpec((BLOCK_N, 3), lambda i, j: (j, 0)),
            pl.BlockSpec((BLOCK_M, 2), lambda i, j: (i, 0)),
            pl.BlockSpec((BLOCK_M, 2), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_M, BLOCK_N, 4), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((m, n, 4), jnp.float32),
        interpret=True,
    )(mu, conic, p_top, p_bot)


# The Eq. 2 threshold rounds on the narrow side of the comparator: FP16
# for the fp16 and mixed schemes, E4M3 for fp8 (rust/src/cat/mixed.rs
# `shared_threshold_quant`).
_LHS_Q = {"fp32": _id, "fp16": _q16, "fp8": _q8, "mixed": _q16}


@functools.partial(jax.jit, static_argnames=("precision",))
def cat_masks(mu, conic, opacity, p_top, p_bot, precision="fp32"):
    """Eq. 2 pass masks from the Pallas weights: ln(255*o) > E.

    Returns (M, N, 4) float32 in {0,1} (bool upsets some PJRT paths).
    """
    e = pr_weights(mu, conic, p_top, p_bot, precision=precision)
    lhs = _LHS_Q[precision](jnp.log(255.0 * jnp.maximum(opacity, 1e-12)))
    return (lhs[None, :, None] > e).astype(jnp.float32)
