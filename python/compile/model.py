"""Layer-2 JAX model: the per-tile rendering pipeline composed from the
Layer-1 Pallas kernels.

Entry points (each AOT-lowered by aot.py to one HLO artifact):

* ``project_entry``    - preprocessing-core datapath for a batch of Gaussians.
* ``pr_weight_entry``  - raw Alg. 1 weights (CTU datapath, fp32 reference).
* ``cat_masks_entry``  - Eq. 2 mini-tile pass decisions for a batch of PRs.
* ``render_tile_entry``- CAT-masked tile render: CAT masks gate which splats
  the blend loop sees, reproducing CTU -> FIFO -> VRU functionally.
* ``render_tiles_entry`` and its ``_fp16``/``_fp8``/``_mixed`` variants -
  batched renders monomorphized per CAT precision class. PJRT executables
  cannot branch on a runtime precision flag, so the adaptive-precision
  executor dispatches each precision-pure wave to its own artifact
  (``render_tile_batched[_fp16|_fp8|_mixed]``).

Shapes are fixed at lowering time (PJRT executables are monomorphic); the
Rust coordinator pads batches to these shapes. Padding convention: splats
with opacity 0 never pass CAT and never blend, so zero-padded tails are
exact no-ops.
"""

import jax
import jax.numpy as jnp

from .kernels.blend import blend_tile
from .kernels.pr_weight import cat_masks, pr_weights
from .kernels.project import project

# Artifact shapes (see aot.py). N = Gaussian batch, M = PR batch.
# M = 16: the four dense PRs of each of the tile's four sub-tiles, so the
# artifact's CAT gate covers the full 16x16 tile (cat::leader::dense_layout).
# B = tiles stacked along the leading dim of the batched render artifact
# (one PJRT dispatch renders up to B tiles; the Rust executor pads ragged
# final batches with zero-opacity rows, which never pass CAT or blend).
N_GAUSS = 256
N_PR = 16
TILE = 16
N_BATCH = 8


def project_entry(pos_cam, cov6_cam, cam_params):
    """(N,3), (N,6), (4,) -> mean (N,2), conic (N,3), depth (N,), radius (N,)."""
    return project(pos_cam, cov6_cam, cam_params)


def pr_weight_entry(mu, conic, p_top, p_bot):
    """(N,2), (N,3), (M,2), (M,2) -> (M,N,4) Alg.1 weights."""
    return (pr_weights(mu, conic, p_top, p_bot, precision="fp32"),)


def cat_masks_entry(mu, conic, opacity, p_top, p_bot):
    """(N,2), (N,3), (N,), (M,2), (M,2) -> (M,N,4) {0,1} pass masks."""
    return (cat_masks(mu, conic, opacity, p_top, p_bot),)


def _render_tile(mu, conic, opacity, color, origin, p_top, p_bot, precision):
    """CAT-gated tile render (the full L1+L2 composition).

    The CAT decision for a splat gates its opacity before blending: a splat
    whose PR corners all fail Eq. 2 in every mini-tile is skipped exactly
    like the hardware drops it from the FIFOs. Gating by opacity keeps the
    blend kernel oblivious to CAT, as the VRUs are. ``precision`` quantizes
    the CAT decision datapath only — blending stays fp32 in every class,
    exactly like the Rust GoldenCat semantics.

    Returns rgb (16,16,3), transmittance (16,16), skip mask (N,).
    """
    masks = cat_masks(mu, conic, opacity, p_top, p_bot, precision=precision)
    passes = jnp.max(masks, axis=(0, 2))  # (N,) 1 if any leader pixel passes
    gated_opacity = opacity * passes
    rgb, trans = blend_tile(mu, conic, gated_opacity, color, origin)
    return rgb, trans, passes


def render_tile_entry(mu, conic, opacity, color, origin, p_top, p_bot):
    """Single-tile fp32 render (see `_render_tile`)."""
    return _render_tile(mu, conic, opacity, color, origin, p_top, p_bot, "fp32")


def _render_tiles(precision):
    """Batched tile render at one CAT precision: `_render_tile` vmapped
    over a leading tile-batch dim B, so one PJRT dispatch renders B tiles.

    Shapes gain a leading B: mu (B,N,2), conic (B,N,3), opacity (B,N),
    color (B,N,3), origin (B,2), p_top/p_bot (B,M,2). Returns rgb
    (B,16,16,3), transmittance (B,16,16), skip masks (B,N). Each batch
    slot is the same per-tile computation as `render_tile_entry` — tiles
    never interact, so slots with zero-opacity padding are exact no-ops
    and the Rust executor may fill a ragged final batch freely.
    """

    def entry(mu, conic, opacity, color, origin, p_top, p_bot):
        return jax.vmap(
            lambda *a: _render_tile(*a, precision)
        )(mu, conic, opacity, color, origin, p_top, p_bot)

    return entry


render_tiles_entry = _render_tiles("fp32")
render_tiles_fp16_entry = _render_tiles("fp16")
render_tiles_fp8_entry = _render_tiles("fp8")
render_tiles_mixed_entry = _render_tiles("mixed")
