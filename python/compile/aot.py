"""AOT lowering: JAX entry points -> HLO *text* artifacts for the Rust
PJRT runtime.

HLO text (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
(the version behind the published ``xla`` crate) rejects; the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/gen_hlo.py.

Run once at build time (``make artifacts``); Python never executes on the
frame-rendering path.
"""

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def entries():
    n, m, t, b = model.N_GAUSS, model.N_PR, model.TILE, model.N_BATCH
    batched_specs = (
        f32(b, n, 2),
        f32(b, n, 3),
        f32(b, n),
        f32(b, n, 3),
        f32(b, 2),
        f32(b, m, 2),
        f32(b, m, 2),
    )
    return {
        "project": (
            model.project_entry,
            (f32(n, 3), f32(n, 6), f32(4)),
        ),
        "pr_weight": (
            model.pr_weight_entry,
            (f32(n, 2), f32(n, 3), f32(m, 2), f32(m, 2)),
        ),
        "cat_masks": (
            model.cat_masks_entry,
            (f32(n, 2), f32(n, 3), f32(n), f32(m, 2), f32(m, 2)),
        ),
        "render_tile": (
            model.render_tile_entry,
            (f32(n, 2), f32(n, 3), f32(n), f32(n, 3), f32(2), f32(m, 2), f32(m, 2)),
        ),
        # Batched variant: B tiles per dispatch along a leading batch dim
        # (manifest field n_batch). The Rust executor drains its tile
        # queue through this artifact and pads ragged final batches with
        # zero-opacity rows (exact no-ops through CAT and blending).
        "render_tile_batched": (model.render_tiles_entry, batched_specs),
        # Per-precision-class monomorphizations of the batched render:
        # the adaptive-precision executor groups classed tiles into
        # precision-pure waves and dispatches each wave to the artifact
        # whose CAT datapath matches its class (fp32 waves reuse the
        # plain `render_tile_batched`). Same shapes, same padding rules.
        "render_tile_batched_fp16": (model.render_tiles_fp16_entry, batched_specs),
        "render_tile_batched_fp8": (model.render_tiles_fp8_entry, batched_specs),
        "render_tile_batched_mixed": (model.render_tiles_mixed_entry, batched_specs),
        "_unused_tile": (lambda: None, (t,)),  # keeps TILE in the manifest
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {
        "n_gauss": model.N_GAUSS,
        "n_pr": model.N_PR,
        "tile": model.TILE,
        "n_batch": model.N_BATCH,
        "artifacts": {},
    }
    for name, (fn, specs) in entries().items():
        if name.startswith("_"):
            continue
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        digest = hashlib.sha256(text.encode()).hexdigest()[:16]
        manifest["artifacts"][name] = {
            "file": f"{name}.hlo.txt",
            "sha256_16": digest,
            "inputs": [list(s.shape) for s in specs],
        }
        print(f"wrote {path} ({len(text)} chars, sha {digest})")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {args.out_dir}/manifest.json")


if __name__ == "__main__":
    main()
