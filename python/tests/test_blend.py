"""Pallas tile-blend kernel vs pure-jnp oracle."""

import numpy as np
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels.blend import blend_tile
from compile.kernels import ref


def make_splats(rng, n, origin=(0.0, 0.0), spread=24.0):
    mu = (
        np.array(origin)[None, :]
        + rng.uniform(-spread * 0.25, spread, size=(n, 2))
    ).astype(np.float32)
    l11 = rng.uniform(0.05, 0.8, size=n).astype(np.float32)
    l21 = rng.uniform(-0.3, 0.3, size=n).astype(np.float32)
    l22 = rng.uniform(0.05, 0.8, size=n).astype(np.float32)
    conic = np.stack([l11 * l11, l11 * l21, l21 * l21 + l22 * l22], axis=-1).astype(
        np.float32
    )
    opacity = rng.uniform(0.0, 1.0, size=n).astype(np.float32)
    color = rng.uniform(0.0, 1.5, size=(n, 3)).astype(np.float32)
    return mu, conic, opacity, color


def run_both(mu, conic, opacity, color, origin):
    got_rgb, got_t = blend_tile(
        jnp.array(mu), jnp.array(conic), jnp.array(opacity), jnp.array(color),
        jnp.array(origin, dtype=jnp.float32),
    )
    want_rgb, want_t = ref.blend_tile_ref(
        jnp.array(mu), jnp.array(conic), jnp.array(opacity), jnp.array(color),
        jnp.array(origin, dtype=jnp.float32),
    )
    return (np.asarray(got_rgb), np.asarray(got_t)), (np.asarray(want_rgb), np.asarray(want_t))


def test_matches_ref_basic():
    rng = np.random.default_rng(0)
    mu, conic, opacity, color = make_splats(rng, 32)
    (g_rgb, g_t), (w_rgb, w_t) = run_both(mu, conic, opacity, color, (0.0, 0.0))
    np.testing.assert_allclose(g_rgb, w_rgb, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(g_t, w_t, rtol=1e-5, atol=1e-6)


def test_empty_opacity_is_background():
    rng = np.random.default_rng(1)
    mu, conic, _, color = make_splats(rng, 8)
    opacity = np.zeros(8, np.float32)
    (g_rgb, g_t), _ = run_both(mu, conic, opacity, color, (0.0, 0.0))
    assert np.allclose(g_rgb, 0.0)
    assert np.allclose(g_t, 1.0)


def test_opaque_front_occludes():
    # One fully opaque splat centered on the tile, then a bright one behind:
    # the back splat's color must be ~absent at the center pixel.
    mu = np.array([[8.0, 8.0], [8.0, 8.0]], np.float32)
    conic = np.array([[0.02, 0.0, 0.02], [0.02, 0.0, 0.02]], np.float32)
    opacity = np.array([1.0, 1.0], np.float32)
    color = np.array([[1.0, 0.0, 0.0], [0.0, 1.0, 0.0]], np.float32)
    (g_rgb, _), (w_rgb, _) = run_both(mu, conic, opacity, color, (0.0, 0.0))
    center = g_rgb[8, 8]
    assert center[0] > 0.99
    assert center[1] < 0.01
    np.testing.assert_allclose(g_rgb, w_rgb, rtol=1e-5, atol=1e-5)


def test_transmittance_monotone_decreasing_with_more_splats():
    rng = np.random.default_rng(2)
    mu, conic, opacity, color = make_splats(rng, 64, spread=12.0)
    (_, t_all), _ = run_both(mu, conic, opacity, color, (0.0, 0.0))
    (_, t_half), _ = run_both(mu[:32], conic[:32], opacity[:32], color[:32], (0.0, 0.0))
    assert (t_all <= t_half + 1e-6).all()


def test_origin_shift_equivariance():
    # Shifting both origin and splats by the same offset gives identical tiles.
    rng = np.random.default_rng(3)
    mu, conic, opacity, color = make_splats(rng, 16)
    (a_rgb, a_t), _ = run_both(mu, conic, opacity, color, (0.0, 0.0))
    shift = np.array([128.0, 64.0], np.float32)
    (b_rgb, b_t), _ = run_both(mu + shift, conic, opacity, color, tuple(shift))
    np.testing.assert_allclose(a_rgb, b_rgb, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(a_t, b_t, rtol=1e-4, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n=st.sampled_from([1, 7, 33, 128]))
def test_hypothesis_sweep(seed, n):
    rng = np.random.default_rng(seed)
    mu, conic, opacity, color = make_splats(rng, n)
    (g_rgb, g_t), (w_rgb, w_t) = run_both(mu, conic, opacity, color, (0.0, 0.0))
    np.testing.assert_allclose(g_rgb, w_rgb, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(g_t, w_t, rtol=1e-4, atol=1e-5)
