"""Always-collectable sanity checks (named *_test.py so conftest's
collect_ignore_glob for the optional-dependency suites never matches this
file). Guarantees pytest collects at least one test and exits 0 even when
jax/hypothesis are absent and the kernel suites are skipped."""
import os

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_compile_package_layout():
    for rel in ("compile/aot.py", "compile/model.py", "compile/kernels/blend.py"):
        assert os.path.exists(os.path.join(ROOT, rel)), rel


def test_conftest_puts_package_on_path():
    import conftest  # noqa: F401  (the tests dir itself is importable)

    assert any(os.path.samefile(p, ROOT) for p in map(os.path.abspath, os.sys.path) if os.path.isdir(p))
