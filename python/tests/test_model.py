"""L2 model entry points: composition + AOT lowering smoke tests."""

import numpy as np

import jax
import jax.numpy as jnp

from compile import model
from compile.aot import entries, to_hlo_text
from compile.kernels import ref


def make_batch(rng, n=model.N_GAUSS, m=model.N_PR):
    mu = rng.uniform(0.0, 16.0, size=(n, 2)).astype(np.float32)
    l11 = rng.uniform(0.1, 0.8, size=n).astype(np.float32)
    l21 = rng.uniform(-0.3, 0.3, size=n).astype(np.float32)
    l22 = rng.uniform(0.1, 0.8, size=n).astype(np.float32)
    conic = np.stack([l11 * l11, l11 * l21, l21 * l21 + l22 * l22], axis=-1).astype(
        np.float32
    )
    opacity = rng.uniform(0.0, 1.0, size=n).astype(np.float32)
    color = rng.uniform(0.0, 1.0, size=(n, 3)).astype(np.float32)
    origin = np.zeros(2, np.float32)
    # Dense PR layout of the 4 mini-tiles of sub-tile (0,0) plus sub-tile
    # (8,8), mirroring cat::leader::dense_layout.
    p_top, p_bot = [], []
    for oy in (0.0, 8.0):
        for m_i in range(4):
            mx, my = (m_i % 2) * 4.0, (m_i // 2) * 4.0
            p_top.append([oy + mx + 0.5, oy + my + 0.5])
            p_bot.append([oy + mx + 3.5, oy + my + 3.5])
    p_top = np.array(p_top[:m], np.float32)
    p_bot = np.array(p_bot[:m], np.float32)
    return mu, conic, opacity, color, origin, p_top, p_bot


def test_render_tile_gates_by_cat():
    rng = np.random.default_rng(0)
    mu, conic, opacity, color, origin, pt, pb = make_batch(rng)
    rgb, trans, passes = model.render_tile_entry(
        *map(jnp.array, (mu, conic, opacity, color, origin, pt, pb))
    )
    assert rgb.shape == (16, 16, 3)
    assert trans.shape == (16, 16)
    p = np.asarray(passes)
    assert set(np.unique(p)).issubset({0.0, 1.0})
    # Gating must equal manually zeroing failed splats.
    want_rgb, want_t = ref.blend_tile_ref(
        jnp.array(mu), jnp.array(conic), jnp.array(opacity * p), jnp.array(color),
        jnp.array(origin),
    )
    np.testing.assert_allclose(np.asarray(rgb), np.asarray(want_rgb), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(trans), np.asarray(want_t), rtol=1e-5, atol=1e-6)


def test_cat_gating_is_conservative_for_big_central_splat():
    # A huge opaque splat centered in the tile must always pass.
    rng = np.random.default_rng(1)
    mu, conic, opacity, color, origin, pt, pb = make_batch(rng)
    mu[0] = [8.0, 8.0]
    conic[0] = [0.01, 0.0, 0.01]
    opacity[0] = 0.95
    _, _, passes = model.render_tile_entry(
        *map(jnp.array, (mu, conic, opacity, color, origin, pt, pb))
    )
    assert np.asarray(passes)[0] == 1.0


def test_zero_opacity_padding_is_noop():
    rng = np.random.default_rng(2)
    mu, conic, opacity, color, origin, pt, pb = make_batch(rng)
    opacity[model.N_GAUSS // 2 :] = 0.0
    rgb_full, _, _ = model.render_tile_entry(
        *map(jnp.array, (mu, conic, opacity, color, origin, pt, pb))
    )
    # Re-run with the tail splats moved far away instead: same image.
    mu2 = mu.copy()
    mu2[model.N_GAUSS // 2 :] = 1e6
    rgb_moved, _, _ = model.render_tile_entry(
        *map(jnp.array, (mu2, conic, opacity, color, origin, pt, pb))
    )
    np.testing.assert_allclose(np.asarray(rgb_full), np.asarray(rgb_moved), atol=1e-5)


def test_render_tiles_entry_matches_per_tile_calls():
    # The batched artifact is render_tile_entry vmapped over a leading
    # batch dim: every slot must reproduce the single-tile entry (the
    # Rust-side differential harness additionally enforces bit-identity
    # of the executor paths against the offline stub).
    rng = np.random.default_rng(3)
    slots = [make_batch(rng) for _ in range(model.N_BATCH)]
    # Give each slot its own tile origin so broadcasting bugs can't hide.
    for b, slot in enumerate(slots):
        slot[4][:] = [16.0 * b, 8.0 * b]
    batched = [jnp.array(np.stack([s[i] for s in slots])) for i in range(7)]
    rgb_b, trans_b, passes_b = model.render_tiles_entry(*batched)
    assert rgb_b.shape == (model.N_BATCH, 16, 16, 3)
    assert trans_b.shape == (model.N_BATCH, 16, 16)
    assert passes_b.shape == (model.N_BATCH, model.N_GAUSS)
    for b, slot in enumerate(slots):
        rgb, trans, passes = model.render_tile_entry(*map(jnp.array, slot))
        np.testing.assert_allclose(np.asarray(rgb_b)[b], np.asarray(rgb), rtol=1e-6, atol=1e-6)
        np.testing.assert_allclose(
            np.asarray(trans_b)[b], np.asarray(trans), rtol=1e-6, atol=1e-7
        )
        np.testing.assert_array_equal(np.asarray(passes_b)[b], np.asarray(passes))


def test_all_entries_lower_to_hlo_text():
    for name, (fn, specs) in entries().items():
        if name.startswith("_"):
            continue
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        assert "HloModule" in text, f"{name}: no HloModule header"
        assert len(text) > 200, f"{name}: suspiciously small"
