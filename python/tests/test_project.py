"""Pallas projection kernel vs pure-jnp oracle."""

import numpy as np
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels.project import BLOCK, project
from compile.kernels import ref


def make_case(rng, n):
    pos = np.stack(
        [
            rng.uniform(-4.0, 4.0, size=n),
            rng.uniform(-4.0, 4.0, size=n),
            rng.uniform(2.0, 30.0, size=n),  # z > 0 (camera space)
        ],
        axis=-1,
    ).astype(np.float32)
    # PSD covariance via random factors L L^T (scaled small, like splats).
    l = rng.normal(0.0, 0.15, size=(n, 3, 3)).astype(np.float32)
    cov33 = np.einsum("nij,nkj->nik", l, l) + 1e-4 * np.eye(3, dtype=np.float32)
    cov6 = np.stack(
        [
            cov33[:, 0, 0], cov33[:, 0, 1], cov33[:, 0, 2],
            cov33[:, 1, 1], cov33[:, 1, 2], cov33[:, 2, 2],
        ],
        axis=-1,
    ).astype(np.float32)
    cam = np.array([300.0, 300.0, 128.0, 128.0], np.float32)
    return pos, cov6, cov33, cam


def run_kernel(pos, cov6, cam):
    mean, conic, depth, radius = project(
        jnp.array(pos), jnp.array(cov6), jnp.array(cam)
    )
    return map(np.asarray, (mean, conic, depth, radius))


def test_matches_ref():
    rng = np.random.default_rng(0)
    pos, cov6, cov33, cam = make_case(rng, BLOCK)
    mean, conic, depth, radius = run_kernel(pos, cov6, cam)
    want = ref.project_ref(
        jnp.array(pos), cam[0], cam[1], cam[2], cam[3], jnp.array(cov33)
    )
    np.testing.assert_allclose(mean, np.asarray(want["mean"]), rtol=1e-5, atol=1e-3)
    np.testing.assert_allclose(conic, np.asarray(want["conic"]), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(depth, np.asarray(want["depth"]), rtol=1e-6)
    np.testing.assert_allclose(radius, np.asarray(want["radius"]), rtol=1e-4, atol=1e-4)


def test_center_maps_to_principal_point():
    rng = np.random.default_rng(1)
    pos, cov6, _, cam = make_case(rng, BLOCK)
    pos[0] = [0.0, 0.0, 10.0]
    mean, _, depth, _ = run_kernel(pos, cov6, cam)
    np.testing.assert_allclose(mean[0], [128.0, 128.0], atol=1e-3)
    assert abs(depth[0] - 10.0) < 1e-5


def test_conic_is_inverse_of_cov():
    rng = np.random.default_rng(2)
    pos, cov6, cov33, cam = make_case(rng, BLOCK)
    _, conic, _, _ = run_kernel(pos, cov6, cam)
    want = ref.project_ref(
        jnp.array(pos), cam[0], cam[1], cam[2], cam[3], jnp.array(cov33)
    )
    cov = np.asarray(want["cov"])
    # conic * cov must reconstruct identity: a*ia + b*ib = 1, etc.
    a, b, c = cov[:, 0], cov[:, 1], cov[:, 2]
    ia, ib, ic = conic[:, 0], conic[:, 1], conic[:, 2]
    np.testing.assert_allclose(a * ia + b * ib, 1.0, atol=1e-3)
    np.testing.assert_allclose(b * ia + c * ib, 0.0, atol=1e-3)
    np.testing.assert_allclose(b * ib + c * ic, 1.0, atol=1e-3)


def test_farther_is_smaller():
    rng = np.random.default_rng(3)
    pos, cov6, _, cam = make_case(rng, BLOCK)
    pos[0] = [0.0, 0.0, 5.0]
    pos[1] = [0.0, 0.0, 20.0]
    cov6[1] = cov6[0]
    _, _, _, radius = run_kernel(pos, cov6, cam)
    assert radius[0] > radius[1]


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), blocks=st.integers(1, 3))
def test_hypothesis_sweep(seed, blocks):
    rng = np.random.default_rng(seed)
    pos, cov6, cov33, cam = make_case(rng, BLOCK * blocks)
    mean, conic, depth, radius = run_kernel(pos, cov6, cam)
    want = ref.project_ref(
        jnp.array(pos), cam[0], cam[1], cam[2], cam[3], jnp.array(cov33)
    )
    np.testing.assert_allclose(mean, np.asarray(want["mean"]), rtol=1e-4, atol=1e-2)
    np.testing.assert_allclose(radius, np.asarray(want["radius"]), rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(depth, np.asarray(want["depth"]), rtol=1e-6)
    np.testing.assert_allclose(conic, np.asarray(want["conic"]), rtol=1e-3, atol=1e-3)
