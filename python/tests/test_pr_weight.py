"""Pallas PR-weight kernel vs pure-jnp oracle (the core L1 signal)."""

import functools

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from compile.kernels.pr_weight import (
    BLOCK_M,
    BLOCK_N,
    PRECISIONS,
    cat_masks,
    pr_weights,
)
from compile.kernels import ref


def make_case(rng, m, n, coord_scale=1000.0):
    mu = rng.uniform(0.0, coord_scale, size=(n, 2)).astype(np.float32)
    # Positive-definite conic via Cholesky factors.
    l11 = rng.uniform(0.05, 1.0, size=n).astype(np.float32)
    l21 = rng.uniform(-0.5, 0.5, size=n).astype(np.float32)
    l22 = rng.uniform(0.05, 1.0, size=n).astype(np.float32)
    conic = np.stack([l11 * l11, l11 * l21, l21 * l21 + l22 * l22], axis=-1)
    p_top = rng.uniform(0.0, coord_scale, size=(m, 2)).astype(np.float32)
    p_bot = p_top + rng.uniform(1.0, 8.0, size=(m, 2)).astype(np.float32)
    opacity = rng.uniform(0.01, 1.0, size=n).astype(np.float32)
    return mu, conic.astype(np.float32), opacity, p_top, p_bot


def test_matches_ref_fp32():
    rng = np.random.default_rng(0)
    mu, conic, _, pt, pb = make_case(rng, BLOCK_M, BLOCK_N)
    got = pr_weights(jnp.array(mu), jnp.array(conic), jnp.array(pt), jnp.array(pb))
    want = ref.pr_weights_ref(jnp.array(mu), jnp.array(conic), jnp.array(pt), jnp.array(pb))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4)


def test_matches_ref_multi_block():
    rng = np.random.default_rng(1)
    mu, conic, _, pt, pb = make_case(rng, BLOCK_M * 3, BLOCK_N * 2)
    got = pr_weights(jnp.array(mu), jnp.array(conic), jnp.array(pt), jnp.array(pb))
    want = ref.pr_weights_ref(jnp.array(mu), jnp.array(conic), jnp.array(pt), jnp.array(pb))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4)


def test_mixed_matches_mixed_ref():
    rng = np.random.default_rng(2)
    mu, conic, _, pt, pb = make_case(rng, BLOCK_M, BLOCK_N)
    got = pr_weights(
        jnp.array(mu), jnp.array(conic), jnp.array(pt), jnp.array(pb), precision="mixed"
    )
    want = ref.pr_weights_mixed_ref(
        jnp.array(mu), jnp.array(conic), jnp.array(pt), jnp.array(pb)
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("precision", PRECISIONS)
def test_quant_schemes_match_ref(precision):
    # One contract per precision class: the Pallas kernel and the pure-jnp
    # oracle insert quantization at the same Alg. 1 points. The oracle runs
    # under jit so both sides get XLA's convert-chain fusion — XLA folds
    # f32->f16->f32 round-trips around an op into genuine f16 arithmetic,
    # whose double rounding differs from eager op-by-op rounding by one
    # f16 ulp on rare inputs.
    rng = np.random.default_rng(7)
    mu, conic, _, pt, pb = make_case(rng, BLOCK_M, BLOCK_N)
    got = pr_weights(
        jnp.array(mu), jnp.array(conic), jnp.array(pt), jnp.array(pb), precision=precision
    )
    oracle = jax.jit(functools.partial(ref.pr_weights_quant_ref, precision=precision))
    want = oracle(jnp.array(mu), jnp.array(conic), jnp.array(pt), jnp.array(pb))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6, atol=1e-6)


def test_mixed_close_to_fp32_near_gaussian():
    # Mixed precision must track fp32 for deltas in the decision-relevant
    # range, i.e. pixels near the Gaussian (the paper's quality argument).
    # Far pixels saturate the FP8 delta at 448 and deviate — by design:
    # those weights are enormous either way and the Eq.-2 decision (E vs
    # ln(255·o) ≤ 5.54) is unaffected.
    rng = np.random.default_rng(3)
    mu, conic, _, _, _ = make_case(rng, BLOCK_M, BLOCK_N)
    base = mu[0]
    mu = (base[None, :] + rng.uniform(-30, 30, size=(BLOCK_N, 2))).astype(np.float32)
    pt = (base[None, :] + rng.uniform(-10, 10, size=(BLOCK_M, 2))).astype(np.float32)
    pb = pt + 3.0
    full = np.asarray(pr_weights(jnp.array(mu), jnp.array(conic), jnp.array(pt), jnp.array(pb)))
    mix = np.asarray(
        pr_weights(
            jnp.array(mu), jnp.array(conic), jnp.array(pt), jnp.array(pb), precision="mixed"
        )
    )
    rel = np.abs(mix - full) / (1.0 + np.abs(full))
    # E4M3 carries ~6% per-operand rounding; squared terms land ~10-12%.
    assert np.mean(rel) < 0.15, f"mean rel err {np.mean(rel)}"


def test_cat_masks_match_ref():
    rng = np.random.default_rng(4)
    mu, conic, opacity, pt, pb = make_case(rng, BLOCK_M, BLOCK_N)
    got = cat_masks(
        jnp.array(mu), jnp.array(conic), jnp.array(opacity), jnp.array(pt), jnp.array(pb)
    )
    want = ref.cat_masks_ref(
        jnp.array(mu), jnp.array(conic), jnp.array(opacity), jnp.array(pt), jnp.array(pb)
    )
    # Decisions may differ only where |lhs - E| is at float noise level.
    got_b = np.asarray(got) > 0.5
    want_b = np.asarray(want)
    disagree = got_b != want_b
    assert disagree.mean() < 1e-3, f"disagreement {disagree.mean()}"


def test_weight_zero_at_mean():
    rng = np.random.default_rng(5)
    mu, conic, _, _, _ = make_case(rng, BLOCK_M, BLOCK_N)
    pt = np.tile(mu[0], (BLOCK_M, 1)).astype(np.float32)
    pb = pt + 4.0
    got = np.asarray(
        pr_weights(jnp.array(mu), jnp.array(conic), jnp.array(pt), jnp.array(pb))
    )
    assert abs(got[0, 0, 0]) < 1e-4


def test_rejects_unpadded_shapes():
    rng = np.random.default_rng(6)
    mu, conic, _, pt, pb = make_case(rng, BLOCK_M, BLOCK_N + 1)
    with pytest.raises(AssertionError):
        pr_weights(jnp.array(mu), jnp.array(conic), jnp.array(pt), jnp.array(pb))


@settings(max_examples=20, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    mblocks=st.integers(1, 2),
    nblocks=st.integers(1, 2),
    scale=st.sampled_from([16.0, 256.0, 2048.0]),
)
def test_hypothesis_sweep_matches_ref(seed, mblocks, nblocks, scale):
    rng = np.random.default_rng(seed)
    mu, conic, _, pt, pb = make_case(rng, BLOCK_M * mblocks, BLOCK_N * nblocks, scale)
    got = pr_weights(jnp.array(mu), jnp.array(conic), jnp.array(pt), jnp.array(pb))
    want = ref.pr_weights_ref(jnp.array(mu), jnp.array(conic), jnp.array(pt), jnp.array(pb))
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-3 * max(1.0, scale / 256.0)
    )
