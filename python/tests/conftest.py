"""Make `compile.*` importable when pytest runs from the repo root, and
skip gracefully when optional dependencies are missing: the kernels (and
all their tests) need `jax`, and the property tests need `hypothesis`."""
import os
import sys

sys.path.insert(0, os.path.abspath(os.path.join(os.path.dirname(__file__), "..")))


def _missing(module):
    try:
        __import__(module)
        return False
    except ImportError:
        return True


collect_ignore_glob = []
if _missing("jax"):
    print("jax unavailable - skipping python kernel tests", file=sys.stderr)
    collect_ignore_glob = ["test_*.py"]
elif _missing("hypothesis"):
    print("hypothesis unavailable - skipping property tests", file=sys.stderr)
    collect_ignore_glob = ["test_blend.py", "test_pr_weight.py", "test_project.py"]
