#!/usr/bin/env python3
"""Merge bench JSON sidecars into one commit-stamped BENCH_10.json.

The bench-record CI lane (push-to-main only) runs the hotpath,
fig11_gating, fig12_temporal, fig13_precision, and fig14_service benches
in quick mode, then calls this script to fold their
`rust/target/bench-reports/*.json` sidecars into a single artifact that
extends the repo's perf trajectory: plan build/reuse/delta timings, PJRT
single-vs-batched dispatch, the coarse-to-fine gating rows
(splats_submitted, per-level reject counts, gating on/off), the temporal
plan-delta amortization sweep (amortized_ratio, rebinned_frac,
entries_carried per orbit step), the adaptive-precision rows (per-class
tile/PR mix, PSNR vs global fp32, CTU energy saving, plus the per-rect
quadrant rows: quads/<class> mix, psnr_rect_vs_fp32, ctu_prs_rect, and
the rect-vs-adaptive CTU saving), and the multi-tenant service rows
(per-client-count latency percentiles, plan sharing, and the coalesced
vs uncoalesced fill rates).

Stdlib only — the CI image must not need pip installs.
"""

import json
import os
import sys

REPORTS = [
    "hotpath",
    "fig11_gating",
    "fig12_temporal",
    "fig13_precision",
    "fig14_service",
]


def main():
    out_path = sys.argv[1] if len(sys.argv) > 1 else "BENCH_10.json"
    report_dir = os.environ.get(
        "FLICKER_BENCH_REPORTS", os.path.join("rust", "target", "bench-reports")
    )
    merged = {"commit": os.environ.get("GITHUB_SHA", "local"), "reports": {}}
    missing = []
    for rid in REPORTS:
        path = os.path.join(report_dir, rid + ".json")
        if not os.path.exists(path):
            missing.append(path)
            continue
        with open(path) as f:
            merged["reports"][rid] = json.load(f)
    if missing:
        sys.exit(
            "missing bench reports: %s (run `make bench-record` first)"
            % ", ".join(missing)
        )
    with open(out_path, "w") as f:
        json.dump(merged, f, indent=2)
        f.write("\n")
    rows = sum(len(r.get("results", [])) for r in merged["reports"].values())
    print(
        "wrote %s: %d rows from %d reports @ %s"
        % (out_path, rows, len(REPORTS), merged["commit"][:12])
    )


if __name__ == "__main__":
    main()
